//! Deterministic PRNG (xoshiro256++) with the distributions the stack
//! needs: uniform, normal (Box–Muller), Zipf (for the synthetic corpus),
//! and shuffling. Every experiment seeds explicitly for reproducibility.

/// xoshiro256++ — fast, high-quality, dependency-free.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second Box–Muller variate
    spare: Option<f32>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare: None }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = (s[0].wrapping_add(s[3]))
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in [0, 1) with f64 resolution.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform in [lo, hi).
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.next_f32()
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        if let Some(v) = self.spare.take() {
            return v;
        }
        let u1 = (1.0 - self.next_f64()) as f32; // (0, 1]
        let u2 = self.next_f32();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f32::consts::PI * u2;
        self.spare = Some(r * theta.sin());
        r * theta.cos()
    }

    /// N(mu, sigma^2).
    pub fn normal_with(&mut self, mu: f32, sigma: f32) -> f32 {
        mu + sigma * self.normal()
    }

    /// Laplace(0, b) — used by clip::aciq tests to synthesize known
    /// distributions.
    pub fn laplace(&mut self, b: f32) -> f32 {
        let u = self.next_f32() - 0.5;
        -b * u.signum() * (1.0 - 2.0 * u.abs()).max(1e-30).ln()
    }

    /// Zipf-distributed rank in [0, n) with exponent `s` (inverse-CDF over
    /// precomputed weights is overkill; rejection-free cumulative table is
    /// built by callers that need bulk sampling — this is the slow path).
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        // draw via cumulative sum on the fly; O(n) worst case but n is
        // only used for tiny alphabets in tests. Corpus generation uses
        // `ZipfTable`.
        let h: f64 = (1..=n).map(|k| (k as f64).powf(-s)).sum();
        let mut u = self.next_f64() * h;
        for k in 1..=n {
            u -= (k as f64).powf(-s);
            if u <= 0.0 {
                return k - 1;
            }
        }
        n - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Vector of standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal()).collect()
    }
}

/// Precomputed Zipf CDF for bulk sampling (synthetic corpus generation).
pub struct ZipfTable {
    cdf: Vec<f64>,
}

impl ZipfTable {
    pub fn new(n: usize, s: f64) -> Self {
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 1..=n {
            acc += (k as f64).powf(-s);
            cdf.push(acc);
        }
        let total = acc;
        for c in cdf.iter_mut() {
            *c /= total;
        }
        ZipfTable { cdf }
    }

    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.next_f64();
        self.cdf.partition_point(|&c| c < u)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut r = Rng::new(7);
        let mut sum = 0.0f64;
        for _ in 0..10_000 {
            let v = r.next_f32();
            assert!((0.0..1.0).contains(&v));
            sum += v as f64;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let n = 50_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn zipf_table_is_monotone_decreasing_in_frequency() {
        let table = ZipfTable::new(50, 1.1);
        let mut r = Rng::new(3);
        let mut counts = vec![0usize; 50];
        for _ in 0..20_000 {
            counts[table.sample(&mut r)] += 1;
        }
        assert!(counts[0] > counts[10]);
        assert!(counts[1] > counts[25]);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(11);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(5);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }
}
