//! Dependency-free substrates: RNG, JSON, TOML-subset config, logging.
//!
//! The offline vendor set ships only `xla`/`anyhow`/`thiserror`, so the
//! usual ecosystem crates (rand, serde_json, toml, env_logger, clap) are
//! reimplemented here at the scale this project needs.

pub mod hash;
pub mod json;
pub mod logging;
pub mod rng;
pub mod toml;

/// `ceil(a / b)` for positive integers.
pub fn ceil_div(a: usize, b: usize) -> usize {
    (a + b - 1) / b
}

/// The paper's rounding function `Q(x) = floor(x + 0.5)` — round half up
/// (toward +inf). This is the convention the quantization-aware splitting
/// proof (§3.3 / Eq. 7) relies on and MUST match the Pallas kernels
/// (`python/compile/kernels/ref.py::round_half_up`).
#[inline(always)]
pub fn round_half_up(x: f32) -> f32 {
    (x + 0.5).floor()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_half_up_convention() {
        assert_eq!(round_half_up(0.5), 1.0);
        assert_eq!(round_half_up(1.5), 2.0);
        assert_eq!(round_half_up(2.5), 3.0); // not banker's rounding
        assert_eq!(round_half_up(-0.5), 0.0); // halves toward +inf
        assert_eq!(round_half_up(-1.5), -1.0);
        assert_eq!(round_half_up(2.4), 2.0);
        assert_eq!(round_half_up(-2.6), -3.0);
    }

    #[test]
    fn ceil_div_basic() {
        assert_eq!(ceil_div(10, 3), 4);
        assert_eq!(ceil_div(9, 3), 3);
        assert_eq!(ceil_div(1, 100), 1);
    }
}
