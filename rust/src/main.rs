//! `ocs` — the leader binary: training, quantization, paper-table
//! regeneration, and a serving self-test, all over the AOT artifacts.
//!
//! ```text
//! ocs info                          inventory of artifacts + layers
//! ocs train --model all|<name>      train through the train_step artifact
//! ocs eval  --model <name> [...]    evaluate one quantization config
//! ocs table --id all|1|2|3|4|5|6|fig1   regenerate paper tables/figures
//! ocs serve --model <name>          dynamic-batching serving self-test
//! ```

use anyhow::{bail, Context, Result};

use ocs::cli::Args;
use ocs::clip::ClipMethod;
use ocs::eval;
use ocs::info;
use ocs::model::store::WeightStore;
use ocs::model::ModelSpec;
use ocs::ocs::{OcsTarget, SplitMode};
use ocs::pipeline::{self, QuantConfig, QuantRecipe};
use ocs::runtime::Engine;
use ocs::tables::TableCtx;
use ocs::train::{self, data};

const USAGE: &str = "\
ocs — Outlier Channel Splitting (ICML'19) quantization stack

USAGE:
  ocs info
  ocs train --model all|minivgg|miniresnet|miniincept|lstmlm [--steps N] [--lr F]
  ocs eval  --model NAME [--w-bits N] [--a-bits N] [--w-clip M] [--a-clip M]
            [--ocs-ratio R] [--ocs-target weights|activations] [--split naive|qa]
            [--layer OVERRIDES]
  ocs table --id all|1|2|3|4|5|6|fig1 [--quick]
  ocs report --model NAME [--bits N] [--ocs-ratio R]
  ocs serve --model NAME [--requests N] [--w-bits N] [--layer OVERRIDES]
            [--workers N] [--queue-cap N] [--deadline-ms MS]
            [--max-batch N] [--max-wait-us US]
            [--sweep 1,2,4] [--json PATH] [--sim]

FLAGS:
  --artifacts DIR   artifact root (default: artifacts)
  --results DIR     table output dir (default: results)
  --threads N       kernel-pool width for the parallel quantization /
                    calibration kernels (default: one per core; results
                    are bit-identical at any width)
  --layer SPECS     per-layer recipe overrides, ';'-separated:
                    'MATCH:key=value,...' where MATCH is a layer-name
                    glob or %first|%last|%edge|%conv|%fc|%embed (combine
                    with '+'), and keys are skip, w_bits, a_bits (0 =
                    float), w_clip, a_clip, ocs_ratio, ocs_target,
                    split_mode. Later overrides win.
                    e.g. --layer 'fc*:w_bits=4;%edge:w_bits=8'
                    (TOML files: [[quant.layer]] tables, same keys plus
                    match/kind/pos)

SERVE FLAGS:
  --workers N       engine shards, one thread+engine each (default: cores)
  --queue-cap N     per-shard queue bound; full queues reject (default 1024)
  --deadline-ms MS  per-request deadline; late jobs get an error response
  --sweep LIST      run the self-test at each worker count, e.g. 1,2,4
  --json PATH       write a BENCH_serving.json throughput/latency record
  --sim             synthetic backend (no artifacts/PJRT needed)
";

fn main() {
    let args = Args::parse_env();
    if let Err(e) = run(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(args: &Args) -> Result<()> {
    let artifacts = args.str_or("artifacts", "artifacts").to_string();
    // install the kernel-pool width before any command touches a hot path
    ocs::pipeline::PerfConfig::from_args(args)?.apply();
    match args.cmd.as_deref() {
        Some("info") => cmd_info(&artifacts),
        Some("train") => cmd_train(args, &artifacts),
        Some("eval") => cmd_eval(args, &artifacts),
        Some("table") => cmd_table(args, &artifacts),
        Some("report") => {
            let model = args.req("model")?;
            ocs::tables::report::run(
                &artifacts,
                args.str_or("results", "results"),
                model,
                args.parse_or("bits", 4u32)?,
                args.parse_or("ocs-ratio", 0.05f64)?,
            )
        }
        Some("serve") => cmd_serve(args, &artifacts),
        Some(other) => bail!("unknown command '{other}'\n{USAGE}"),
        None => {
            print!("{USAGE}");
            Ok(())
        }
    }
}

fn all_models(artifacts: &str) -> Result<Vec<String>> {
    let manifest = std::path::Path::new(artifacts).join("manifest.json");
    let text = std::fs::read_to_string(&manifest)
        .with_context(|| format!("read {} — run `make artifacts` first", manifest.display()))?;
    let v = ocs::util::json::Value::parse(&text)?;
    Ok(v.get("models")?
        .as_arr()?
        .iter()
        .filter_map(|m| m.as_str().ok().map(String::from))
        .collect())
}

fn cmd_info(artifacts: &str) -> Result<()> {
    for name in all_models(artifacts)? {
        let spec = ModelSpec::load_named(artifacts, &name)?;
        let (ws, trained) = WeightStore::load_best(&spec)?;
        println!(
            "{name}: {} layers ({} quantized), {} params, artifacts: {:?}{}",
            spec.layers.len(),
            spec.quantized_layers().count(),
            ws.param_count(),
            spec.artifacts.keys().collect::<Vec<_>>(),
            if trained { " [trained]" } else { " [init only]" }
        );
    }
    Ok(())
}

/// Per-model training defaults: (steps, base lr).
pub fn train_defaults(model: &str) -> (usize, f32) {
    match model {
        "lstmlm" => (1200, 0.7),
        "miniresnet" => (700, 0.015),
        _ => (600, 0.04),
    }
}

fn cmd_train(args: &Args, artifacts: &str) -> Result<()> {
    let which = args.req("model")?;
    let models: Vec<String> = if which == "all" {
        all_models(artifacts)?
    } else {
        vec![which.to_string()]
    };
    let engine = Engine::cpu()?;
    for name in models {
        let spec = ModelSpec::load_named(artifacts, &name)?;
        let ws = WeightStore::load_init(&spec)?;
        let (dsteps, dlr) = train_defaults(&name);
        let steps = args.parse_or("steps", dsteps)?;
        let lr = args.parse_or("lr", dlr)?;
        info!("training {name} for {steps} steps (lr {lr})");
        let (trained, report) = if spec.is_lm() {
            let corpus = data::synth_corpus(200_000, spec.vocab, 91);
            train::train_lm(&engine, &spec, &ws, &corpus, steps, lr, 17)?
        } else {
            let dataset = data::synth_images(8_000, 23);
            train::train_cnn(&engine, &spec, &ws, &dataset, steps, lr, 17)?
        };
        let path = WeightStore::trained_path(&spec);
        trained.save(&path)?;
        info!(
            "{name}: final loss {:.4} -> {}",
            report.final_loss,
            path.display()
        );
    }
    Ok(())
}

fn parse_config(args: &Args) -> Result<QuantConfig> {
    let mut cfg = QuantConfig::float();
    let wb: u32 = args.parse_or("w-bits", 0)?;
    if wb > 0 {
        cfg.w_bits = Some(wb);
    }
    let ab: u32 = args.parse_or("a-bits", 0)?;
    if ab > 0 {
        cfg.a_bits = Some(ab);
    }
    cfg.w_clip = ClipMethod::parse(args.str_or("w-clip", "none"))
        .context("bad --w-clip (none|mse|aciq|kl|percentile[:p])")?;
    cfg.a_clip = ClipMethod::parse(args.str_or("a-clip", "none"))
        .context("bad --a-clip")?;
    cfg.ocs_ratio = args.parse_or("ocs-ratio", 0.0)?;
    cfg.ocs_target = match args.str_or("ocs-target", "weights") {
        "weights" => OcsTarget::Weights,
        "activations" => OcsTarget::Activations,
        other => bail!("bad --ocs-target '{other}'"),
    };
    cfg.split_mode =
        SplitMode::parse(args.str_or("split", "qa")).context("bad --split (naive|qa)")?;
    Ok(cfg)
}

/// Full recipe from the CLI: uniform defaults (`parse_config`) plus any
/// `--layer` per-layer overrides.
fn parse_recipe(args: &Args) -> Result<QuantRecipe> {
    let recipe = parse_config(args)?.to_recipe();
    match args.str("layer") {
        Some(flag) => recipe.with_cli_overrides(flag).context("bad --layer"),
        None => Ok(recipe),
    }
}

fn cmd_eval(args: &Args, artifacts: &str) -> Result<()> {
    let name = args.req("model")?;
    let spec = ModelSpec::load_named(artifacts, name)?;
    let (ws, trained) = WeightStore::load_best(&spec)?;
    if !trained {
        ocs::warnln!("no trained weights for {name}; evaluating the init seed (run `ocs train` first)");
    }
    let recipe = parse_recipe(args)?;
    let engine = Engine::cpu()?;
    if spec.is_lm() {
        let corpus = data::synth_corpus(40_000, spec.vocab, 92);
        let windows = data::token_windows(&corpus, spec.seq_len, 32);
        let prep = pipeline::prepare_recipe(&spec, &ws, None, &recipe)?;
        let ppl = eval::perplexity(&engine, &spec, &prep, &windows)?;
        println!("{name} [{}]: perplexity {ppl:.2}", recipe.label());
    } else {
        let calib = if recipe.needs_calibration(&spec) {
            let calib_set = data::synth_images(256, 29);
            Some(ocs::calib::calibrate(&engine, &spec, &ws, &calib_set.x, 32)?)
        } else {
            None
        };
        let test = data::synth_images(2_000, 31);
        let prep = pipeline::prepare_recipe(&spec, &ws, calib.as_ref(), &recipe)?;
        let acc = eval::accuracy(&engine, &spec, &prep, &test.x, &test.y, 128)?;
        println!("{name} [{}]: top-1 {:.2}%", recipe.label(), acc * 100.0);
    }
    Ok(())
}

fn cmd_table(args: &Args, artifacts: &str) -> Result<()> {
    let id = args.str_or("id", "all");
    let ctx = TableCtx::new(
        artifacts,
        args.str_or("results", "results"),
        args.bool_or("quick", false),
    )?;
    ctx.run(id)
}

fn cmd_serve(args: &Args, artifacts: &str) -> Result<()> {
    let requests: usize = args.parse_or("requests", 512)?;
    let serve_cfg = ocs::pipeline::ServeConfig::from_args(args)?;
    let mut sweep = Vec::new();
    for s in args.list("sweep") {
        match s.parse::<usize>() {
            Ok(w) => sweep.push(w),
            Err(_) => bail!("--sweep: cannot parse '{s}' as a worker count"),
        }
    }
    let json_out = args.str("json").map(std::path::PathBuf::from);
    if args.bool_or("sim", false) {
        return ocs::serve::self_test_sim(requests, &serve_cfg, &sweep, json_out.as_deref());
    }
    let name = args.req("model")?;
    let wb: u32 = args.parse_or("w-bits", 5)?;
    let mut recipe = QuantConfig::weights_only(wb, ClipMethod::Mse, 0.02).to_recipe();
    if let Some(flag) = args.str("layer") {
        recipe = recipe.with_cli_overrides(flag).context("bad --layer")?;
    }
    ocs::serve::self_test(
        artifacts,
        name,
        recipe,
        requests,
        &serve_cfg,
        &sweep,
        json_out.as_deref(),
    )
}
