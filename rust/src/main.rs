//! `ocs` — the leader binary: training, quantization, paper-table
//! regeneration, and a serving self-test, all over the AOT artifacts.
//!
//! ```text
//! ocs info                          inventory of artifacts + layers
//! ocs train --model all|<name>      train through the train_step artifact
//! ocs eval  --model <name> [...]    evaluate one quantization config
//! ocs table --id all|1|2|3|4|5|6|fig1   regenerate paper tables/figures
//! ocs serve --model <name>          dynamic-batching serving self-test
//! ocs serve --loadtest              closed-loop per-tenant load harness
//! ocs autotune                      budgeted mixed-precision recipe search
//! ocs bench check|diff|history      validate / gate / track benchmark records
//! ```

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use ocs::autotune;
use ocs::bench_record::BenchRecord;
use ocs::cli::Args;
use ocs::clip::ClipMethod;
use ocs::eval;
use ocs::info;
use ocs::model::store::WeightStore;
use ocs::model::ModelSpec;
use ocs::ocs::{OcsTarget, SplitMode};
use ocs::pipeline::{self, PreparedCache, QuantConfig, QuantRecipe, ServeBackend, TenantSpec};
use ocs::runtime::native::{native_calibrate, NativeEngine};
use ocs::runtime::Engine;
use ocs::serve::backend::{NativeFactory, PjrtFactory, SimFactory};
use ocs::serve::TenantInit;
use ocs::tables::TableCtx;
use ocs::train::{self, data};

const USAGE: &str = "\
ocs — Outlier Channel Splitting (ICML'19) quantization stack

USAGE:
  ocs info
  ocs train --model all|minivgg|miniresnet|miniincept|lstmlm [--steps N] [--lr F]
  ocs eval  --model NAME [--w-bits N] [--a-bits N] [--w-clip M] [--a-clip M]
            [--ocs-ratio R] [--ocs-target weights|activations] [--split naive|qa]
            [--layer OVERRIDES] [--backend pjrt|native]
  ocs table --id all|1|2|3|4|5|6|fig1 [--quick]
  ocs table --recipe PATH [--model NAME]   (score one emitted recipe)
  ocs report --model NAME [--bits N] [--ocs-ratio R]
  ocs serve --model NAME [--requests N] [--w-bits N] [--a-bits N]
            [--layer OVERRIDES]
            [--workers N] [--queue-cap N] [--deadline-ms MS]
            [--max-batch N] [--max-wait-us US]
            [--sweep 1,2,4] [--json PATH]
            [--backend pjrt|sim|native] [--sim] [--sim-free]
  ocs serve --loadtest [--tenants SPECS] [--clients 1,2,4,8]
            [--requests N] [--json PATH] [--backend pjrt|sim|native]
  ocs autotune --backend native [--model NAME | --sim-free]
            [--ladder 8,6,5,4] [--a-bits 8] [--clips none,mse]
            [--ocs-ratios 0,0.02,0.05] [--acc-drop F] [--allow-skip]
            [--footprint-budget BYTES] [--latency-budget-us US]
            [--beam N] [--group-by layer|kind] [--out PATH] [--json PATH]
  ocs bench check FILE [--bench TAG] [--require P1,P2,...]
            [--speedup-prefix P] [--min-speedup X]
  ocs bench diff OLD NEW [--threshold R] [--summary PATH]
            [--allow-regression]
  ocs bench history DIR [--summary PATH]

FLAGS:
  --artifacts DIR   artifact root (default: artifacts)
  --results DIR     table output dir (default: results)
  --threads N       kernel-pool width for the parallel quantization /
                    calibration kernels (default: one per core; results
                    are bit-identical at any width)
  --layer SPECS     per-layer recipe overrides, ';'-separated:
                    'MATCH:key=value,...' where MATCH is a layer-name
                    glob or %first|%last|%edge|%conv|%fc|%embed (combine
                    with '+'), and keys are skip, w_bits, a_bits (0 =
                    float), w_clip, a_clip, ocs_ratio, ocs_target,
                    split_mode. Later overrides win.
                    e.g. --layer 'fc*:w_bits=4;%edge:w_bits=8'
                    (TOML files: [[quant.layer]] tables, same keys plus
                    match/kind/pos)
  --recipe PATH     eval/serve/table: load the full recipe from a TOML
                    file ([quant] defaults + [[quant.layer]] tables —
                    the format `ocs autotune` emits) instead of the flag
                    defaults; --layer overrides still append on top
                    (eval/serve; `ocs table --recipe` scores the file
                    against the float baseline)

SERVE FLAGS:
  --workers N       engine shards, one thread+engine each (default: cores)
  --queue-cap N     per-shard queue bound; full queues reject (default 1024)
  --deadline-ms MS  per-request deadline; late jobs get an error response
  --sweep LIST      run the self-test at each worker count, e.g. 1,2,4
  --json PATH       write a BENCH_serving.json throughput/latency record
  --backend B       worker engine: pjrt (artifacts, default), sim
                    (synthetic), native (packed i8 GEMM — real quantized
                    compute, no PJRT; TOML: serve.backend). The native
                    backend defaults to --a-bits 8 so its hot path is
                    the integer GEMM (--a-bits 0 forces the f32 body)
  --sim             alias for --backend sim
  --sim-free        with --backend native: serve the built-in synthetic
                    MLP instead of an artifacts-dir model (no --model)
  --prep-cache-cap N  bound the prepared-model LRU cache (default 64,
                    0 = unbounded; evictions are counted in the report)
  --tenant-quota F  cap each tenant at F (0,1] of the pool's queue slots;
                    over-quota submits are rejected and counted per
                    tenant (TOML: serve.tenant_quota)
  --restart-max N   respawns the supervisor grants a crashing worker
                    before opening its breaker (default 3, 0 = never
                    respawn; TOML: serve.restart_max)
  --backoff-ms MS   base respawn backoff, doubled per attempt, capped at
                    64x, and spread by a deterministic ±25% per-worker
                    jitter (default 25; TOML: serve.backoff_ms)
  --tenant-restart-max N  contained failures (panicking batch, aborted
                    recipe sync) a tenant may accumulate before its
                    circuit breaker quarantines it at the router
                    (default 3; TOML: serve.tenant_restart_max)
  --quarantine-ms MS  how long a quarantined tenant is rejected before a
                    single half-open probe may re-admit it (default 250;
                    TOML: serve.quarantine_ms)
  --tenant-fallback serve a quarantined tenant's requests on the default
                    prep instead of rejecting them (TOML:
                    serve.tenant_fallback)
  --fault SPECS     deterministic fault injection, comma-separated:
                    build-fail:W[@N] (worker W's Nth engine build fails,
                    default first), panic:W@N (worker W panics on its
                    Nth batch), slow:US (every batch sleeps US extra
                    microseconds), error-tenant:NAME (that tenant's
                    batches error; siblings unaffected),
                    panic-tenant:NAME (that tenant's batches panic —
                    persistent, the crash-looping-tenant drill),
                    panic-on-sync:NAME@N (the Nth recipe sync for that
                    tenant panics mid-swap; the struck worker rolls back
                    to its previous prep). Build/panic/sync faults fire
                    once. TOML: serve.fault = "..."

LOADTEST FLAGS (ocs serve --loadtest — closed-loop offered-load sweep
over a tenant mix at a fixed --workers count; saturation = the peak-
throughput step):
  --tenants SPECS   extra tenants, comma-separated name[:weight[:wbits]]
                    (e.g. 'gold:1:8,bulk:3'); the implicit 'default'
                    tenant (weight 1, the pool recipe) always serves.
                    TOML files: [[serve.tenant]] tables with name /
                    weight / w_bits / a_bits / ocs_ratio keys
  --clients LIST    offered-load sweep as client counts (default 1,2,4,8)
  --requests N      total requests per step, split across the clients
  --json PATH       BenchRecord output (default BENCH_loadtest.json)
  --chaos           chaos gate instead of the sweep: measure a healthy
                    baseline, kill 1 of N workers mid-load (injected
                    panic), and assert no client hangs, a bounded error
                    burst, and post-respawn recovery; writes a
                    BENCH_chaos.json record (first --clients entry is
                    the concurrency, default 2x workers)
  --chaos-matrix    chaos drill matrix instead of the sweep: single-kill,
                    concurrent multi-worker kills, a panic mid-hot-swap
                    (worker must roll back, not die), and a
                    crash-looping tenant (quarantined by the tenant
                    breaker, no worker breaker opens) — each gated on
                    containment (sibling logits bit-stable, no client
                    hangs, recovery >= 50% of healthy); writes a
                    BENCH_chaos_matrix.json record
  --slow-drill      slow-worker gate instead of the sweep: healthy
                    baseline, then every batch slowed by --slow-us with
                    the deadline disarmed (collapse), then re-armed —
                    asserts the deadline path sheds (fast expiry
                    answers) instead of queueing behind the slow
                    engine; needs --deadline-ms, writes BENCH_slow.json
  --slow-us US      per-batch slowdown for --slow-drill (default 10000)

AUTOTUNE FLAGS (ocs autotune — search per-layer {w_bits, a_bits, clip,
ocs_ratio, skip} policies on the native backend under an accuracy
floor; the winner is emitted as a [[quant.layer]] TOML that serve/eval
load via --recipe, and the search journal as BENCH_autotune.json):
  --ladder LIST     w_bits candidates, descending; LIST[0] is the
                    uniform start + baseline (default 8,6,5,4)
  --a-bits LIST     a_bits candidates, descending (default 8; 0 = float
                    activations, only alone)
  --clips LIST      weight-clip candidates re-chosen at each bit drop
                    (default none,mse)
  --a-clip M        fixed activation clip (default mse)
  --ocs-ratios LIST OCS ratio candidates (default 0,0.02,0.05)
  --acc-drop F      accuracy floor = float accuracy - F (default 0.02)
  --footprint-budget BYTES  stop descending once the winner fits
  --latency-budget-us US    reject candidates over the measured GEMM
                    latency model (measured => winners stop being
                    seed-reproducible)
  --beam N          beam width (default 1 = greedy bit-ladder descent)
  --max-evals N     hard cap on candidates prepared (default 512)
  --allow-skip      let the search keep a group float to rescue the
                    accuracy floor (a float body is larger, never
                    smaller)
  --group-by G      search unit: layer (default) or kind
  --calib N / --test N / --seed S   calibration/held-out sizes + seed
  --cache-cap N     bound the search's private prep cache (0 = unbounded)
  --out PATH        winning recipe TOML (default recipe_autotuned.toml)
  --json PATH       BENCH_autotune.json journal (default off)

EVAL FLAGS:
  --backend B       pjrt (artifacts, default) or native: evaluate on the
                    native integer backend — real quantized arithmetic,
                    works on the stub build (CNN models only)

BENCH FLAGS (records are versioned JSON — see docs/BENCH_FORMAT.md;
baselines live under records/, regenerate with `make bench-record`):
  --bench TAG       check: require the record's bench tag to equal TAG
  --require LIST    check: comma-separated case-name prefixes; each must
                    match at least one measurement row
  --speedup-prefix P  check: rows matching P must include a parallel
                    (threads > 1) run ...
  --min-speedup X   ...whose best speedup_vs_serial exceeds X (default 1)
  --threshold R     diff: relative noise threshold (default 0.25; CI's
                    cross-host gate uses a far more generous tripwire)
  --summary PATH    diff/history: append the markdown table to PATH
                    (CI points this at $GITHUB_STEP_SUMMARY)
  --allow-regression  diff: print the table but always exit 0

  history DIR renders one trajectory table per bench tag over every
  record in DIR (filename order; date-stamped snapshots sort
  chronologically). Unreadable files are listed and skipped.
";

fn main() {
    let args = Args::parse_env();
    if let Err(e) = run(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(args: &Args) -> Result<()> {
    let artifacts = args.str_or("artifacts", "artifacts").to_string();
    // install the kernel-pool width before any command touches a hot path
    ocs::pipeline::PerfConfig::from_args(args)?.apply();
    if let Some(cap) = args.parse_opt::<usize>("prep-cache-cap")? {
        PreparedCache::global().set_capacity(cap);
    }
    match args.cmd.as_deref() {
        Some("info") => cmd_info(&artifacts),
        Some("train") => cmd_train(args, &artifacts),
        Some("eval") => cmd_eval(args, &artifacts),
        Some("table") => cmd_table(args, &artifacts),
        Some("report") => {
            let model = args.req("model")?;
            ocs::tables::report::run(
                &artifacts,
                args.str_or("results", "results"),
                model,
                args.parse_or("bits", 4u32)?,
                args.parse_or("ocs-ratio", 0.05f64)?,
            )
        }
        Some("serve") => cmd_serve(args, &artifacts),
        Some("autotune") => cmd_autotune(args, &artifacts),
        Some("bench") => cmd_bench(args),
        Some(other) => bail!("unknown command '{other}'\n{USAGE}"),
        None => {
            print!("{USAGE}");
            Ok(())
        }
    }
}

fn all_models(artifacts: &str) -> Result<Vec<String>> {
    let manifest = std::path::Path::new(artifacts).join("manifest.json");
    let text = std::fs::read_to_string(&manifest)
        .with_context(|| format!("read {} — run `make artifacts` first", manifest.display()))?;
    let v = ocs::util::json::Value::parse(&text)?;
    Ok(v.get("models")?
        .as_arr()?
        .iter()
        .filter_map(|m| m.as_str().ok().map(String::from))
        .collect())
}

fn cmd_info(artifacts: &str) -> Result<()> {
    for name in all_models(artifacts)? {
        let spec = ModelSpec::load_named(artifacts, &name)?;
        let (ws, trained) = WeightStore::load_best(&spec)?;
        println!(
            "{name}: {} layers ({} quantized), {} params, artifacts: {:?}{}",
            spec.layers.len(),
            spec.quantized_layers().count(),
            ws.param_count(),
            spec.artifacts.keys().collect::<Vec<_>>(),
            if trained { " [trained]" } else { " [init only]" }
        );
    }
    Ok(())
}

/// Per-model training defaults: (steps, base lr).
pub fn train_defaults(model: &str) -> (usize, f32) {
    match model {
        "lstmlm" => (1200, 0.7),
        "miniresnet" => (700, 0.015),
        _ => (600, 0.04),
    }
}

fn cmd_train(args: &Args, artifacts: &str) -> Result<()> {
    let which = args.req("model")?;
    let models: Vec<String> = if which == "all" {
        all_models(artifacts)?
    } else {
        vec![which.to_string()]
    };
    let engine = Engine::cpu()?;
    for name in models {
        let spec = ModelSpec::load_named(artifacts, &name)?;
        let ws = WeightStore::load_init(&spec)?;
        let (dsteps, dlr) = train_defaults(&name);
        let steps = args.parse_or("steps", dsteps)?;
        let lr = args.parse_or("lr", dlr)?;
        info!("training {name} for {steps} steps (lr {lr})");
        let (trained, report) = if spec.is_lm() {
            let corpus = data::synth_corpus(200_000, spec.vocab, 91);
            train::train_lm(&engine, &spec, &ws, &corpus, steps, lr, 17)?
        } else {
            let dataset = data::synth_images(8_000, 23);
            train::train_cnn(&engine, &spec, &ws, &dataset, steps, lr, 17)?
        };
        let path = WeightStore::trained_path(&spec);
        trained.save(&path)?;
        info!(
            "{name}: final loss {:.4} -> {}",
            report.final_loss,
            path.display()
        );
    }
    Ok(())
}

fn parse_config(args: &Args) -> Result<QuantConfig> {
    let mut cfg = QuantConfig::float();
    let wb: u32 = args.parse_or("w-bits", 0)?;
    if wb > 0 {
        cfg.w_bits = Some(wb);
    }
    let ab: u32 = args.parse_or("a-bits", 0)?;
    if ab > 0 {
        cfg.a_bits = Some(ab);
    }
    cfg.w_clip = ClipMethod::parse(args.str_or("w-clip", "none"))
        .context("bad --w-clip (none|mse|aciq|kl|percentile[:p])")?;
    cfg.a_clip = ClipMethod::parse(args.str_or("a-clip", "none"))
        .context("bad --a-clip")?;
    cfg.ocs_ratio = args.parse_or("ocs-ratio", 0.0)?;
    cfg.ocs_target = match args.str_or("ocs-target", "weights") {
        "weights" => OcsTarget::Weights,
        "activations" => OcsTarget::Activations,
        other => bail!("bad --ocs-target '{other}'"),
    };
    cfg.split_mode =
        SplitMode::parse(args.str_or("split", "qa")).context("bad --split (naive|qa)")?;
    Ok(cfg)
}

/// Load a full recipe from a `--recipe` TOML file (`[quant]` defaults +
/// `[[quant.layer]]` tables — the emit format of `ocs autotune`).
fn recipe_from_file(path: &str) -> Result<QuantRecipe> {
    let c = ocs::util::toml::Config::load(path)
        .with_context(|| format!("read recipe file {path}"))?;
    QuantRecipe::from_toml(&c, "quant").with_context(|| format!("bad recipe file {path}"))
}

/// Full recipe from the CLI: a `--recipe` TOML file when given,
/// otherwise uniform defaults (`parse_config`); `--layer` per-layer
/// overrides append either way.
fn parse_recipe(args: &Args) -> Result<QuantRecipe> {
    let recipe = match args.str("recipe") {
        Some(path) => recipe_from_file(path)?,
        None => parse_config(args)?.to_recipe(),
    };
    match args.str("layer") {
        Some(flag) => recipe.with_cli_overrides(flag).context("bad --layer"),
        None => Ok(recipe),
    }
}

fn cmd_eval(args: &Args, artifacts: &str) -> Result<()> {
    let name = args.req("model")?;
    let spec = ModelSpec::load_named(artifacts, name)?;
    let (ws, trained) = WeightStore::load_best(&spec)?;
    if !trained {
        ocs::warnln!("no trained weights for {name}; evaluating the init seed (run `ocs train` first)");
    }
    let recipe = parse_recipe(args)?;
    match ServeBackend::from_args(args)? {
        ServeBackend::Pjrt => {}
        ServeBackend::Native => return eval_native(&spec, &ws, &recipe),
        ServeBackend::Sim => bail!("eval has no sim backend (--backend pjrt|native)"),
    }
    let engine = Engine::cpu()?;
    if spec.is_lm() {
        let corpus = data::synth_corpus(40_000, spec.vocab, 92);
        let windows = data::token_windows(&corpus, spec.seq_len, 32);
        let prep = pipeline::prepare_recipe(&spec, &ws, None, &recipe)?;
        let ppl = eval::perplexity(&engine, &spec, &prep, &windows)?;
        println!("{name} [{}]: perplexity {ppl:.2}", recipe.label());
    } else {
        let calib = if recipe.needs_calibration(&spec) {
            let calib_set = data::synth_images(256, 29);
            Some(ocs::calib::calibrate(&engine, &spec, &ws, &calib_set.x, 32)?)
        } else {
            None
        };
        let test = data::synth_images(2_000, 31);
        let prep = pipeline::prepare_recipe(&spec, &ws, calib.as_ref(), &recipe)?;
        let acc = eval::accuracy(&engine, &spec, &prep, &test.x, &test.y, 128)?;
        println!("{name} [{}]: top-1 {:.2}%", recipe.label(), acc * 100.0);
    }
    Ok(())
}

/// `ocs eval --backend native`: CNN accuracy on the integer backend —
/// real quantized compute, no artifact execution (works on the stub
/// build, where the PJRT path can only error).
fn eval_native(spec: &ModelSpec, ws: &WeightStore, recipe: &QuantRecipe) -> Result<()> {
    if spec.is_lm() {
        bail!("--backend native evaluates the CNN models (the LSTM LM is artifact-only)");
    }
    let calib = if recipe.needs_calibration(spec) {
        let calib_set = data::synth_images(256, 29);
        Some(native_calibrate(spec, ws, &calib_set.x, 32)?)
    } else {
        None
    };
    let prep = pipeline::prepare_recipe(spec, ws, calib.as_ref(), recipe)?;
    let engine = NativeEngine::new(spec.clone());
    let exe = engine.load(&prep)?;
    let test = data::synth_images(2_000, 31);
    let acc = eval::accuracy_native(&exe, &test.x, &test.y, 128)?;
    println!(
        "{} [{}] (native, {} int / {} f32 layers): top-1 {:.2}%",
        spec.name,
        recipe.label(),
        exe.int_layers(),
        exe.float_layers(),
        acc * 100.0
    );
    Ok(())
}

fn cmd_table(args: &Args, artifacts: &str) -> Result<()> {
    let id = args.str_or("id", "all");
    let ctx = TableCtx::new(
        artifacts,
        args.str_or("results", "results"),
        args.bool_or("quick", false),
    )?;
    // `--recipe FILE` scores one emitted recipe (the autotune winner)
    // instead of regenerating a paper table
    if let Some(path) = args.str("recipe") {
        let recipe = recipe_from_file(path)?;
        return ctx.recipe_report(args.str_or("model", ocs::tables::T1_MODEL), &recipe, path);
    }
    ctx.run(id)
}

/// The serve-time default recipe (5-bit MSE-clipped weights, a little
/// OCS) plus any `--w-bits` / `--a-bits` / `--layer` overrides.
/// `default_a_bits` is backend-dependent: the native backend defaults
/// to 8-bit activations so its hot path is the packed i8×i8 GEMM (with
/// float activations every layer would fall back to the f32 body); the
/// PJRT path keeps its historical weights-only default.
fn serve_recipe(args: &Args, default_a_bits: u32) -> Result<QuantRecipe> {
    let mut recipe = match args.str("recipe") {
        // a --recipe TOML is the whole policy — autotune winners carry
        // their own per-layer bits, so the flag defaults stay out
        Some(path) => recipe_from_file(path)?,
        None => {
            let wb: u32 = args.parse_or("w-bits", 5)?;
            let mut cfg = QuantConfig::weights_only(wb, ClipMethod::Mse, 0.02);
            let ab: u32 = args.parse_or("a-bits", default_a_bits)?;
            if ab > 0 {
                cfg.a_bits = Some(ab);
            }
            cfg.to_recipe()
        }
    };
    if let Some(flag) = args.str("layer") {
        recipe = recipe.with_cli_overrides(flag).context("bad --layer")?;
    }
    Ok(recipe)
}

/// `ocs bench check|diff` over versioned records (`bench_record`) —
/// the regression gate CI runs against the baselines under `records/`.
fn cmd_bench(args: &Args) -> Result<()> {
    match args.positional.first().map(String::as_str) {
        Some("check") => bench_check(args),
        Some("diff") => bench_diff(args),
        Some("history") => bench_history(args),
        Some(other) => bail!("unknown bench subcommand '{other}' (check|diff|history)\n{USAGE}"),
        None => bail!(
            "usage: ocs bench check FILE | ocs bench diff OLD NEW | ocs bench history DIR\n{USAGE}"
        ),
    }
}

/// `ocs bench history DIR`: the trajectory view — one table per bench
/// tag over every record in DIR, optionally appended (as markdown) to
/// a summary file. CI points --summary at $GITHUB_STEP_SUMMARY so the
/// bench-gate job shows where each metric has been going, not just
/// whether this PR moved it.
fn bench_history(args: &Args) -> Result<()> {
    let dir = std::path::Path::new(
        args.positional
            .get(1)
            .map(String::as_str)
            .context("usage: ocs bench history DIR [--summary PATH]")?,
    );
    let h = ocs::bench_record::history::load_dir(dir)?;
    print!("{}", h.table());
    if let Some(summary) = args.str("summary") {
        use std::io::Write as _;
        let mut f = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(summary)
            .with_context(|| format!("open summary file {summary}"))?;
        f.write_all(h.markdown().as_bytes())
            .with_context(|| format!("append to summary file {summary}"))?;
    }
    Ok(())
}

fn bench_check(args: &Args) -> Result<()> {
    let path = std::path::Path::new(
        args.positional
            .get(1)
            .map(String::as_str)
            .context("usage: ocs bench check FILE [--bench TAG] [--require P1,P2] [--speedup-prefix P --min-speedup X]")?,
    );
    let rec = BenchRecord::load(path)?;
    rec.validate()
        .with_context(|| format!("invalid bench record {}", path.display()))?;
    if let Some(tag) = args.str("bench") {
        if rec.bench != tag {
            bail!(
                "{}: bench tag '{}' but expected '{tag}'",
                path.display(),
                rec.bench
            );
        }
    }
    for prefix in args.list("require") {
        if !rec.rows.iter().any(|r| r.name.starts_with(prefix.as_str())) {
            bail!(
                "{}: no case matches required prefix '{prefix}'",
                path.display()
            );
        }
    }
    let mut speedup_note = String::new();
    if let Some(prefix) = args.str("speedup-prefix") {
        let min: f64 = args.parse_or("min-speedup", 1.0)?;
        let best = rec.best_parallel_speedup(prefix).with_context(|| {
            format!(
                "{}: no parallel (threads > 1) case matches '{prefix}'",
                path.display()
            )
        })?;
        if best <= min {
            bail!(
                "{}: best parallel speedup for '{prefix}' is {best:.2}x (need > {min:.2}x)",
                path.display()
            );
        }
        speedup_note = format!(", best '{prefix}' parallel speedup {best:.2}x");
    }
    println!(
        "{}: ok — bench '{}', {} row(s), {}/{} {}t{}{}",
        path.display(),
        rec.bench,
        rec.rows.len(),
        rec.host.os,
        rec.host.arch,
        rec.host.threads_available,
        if rec.quick { " quick" } else { "" },
        speedup_note
    );
    Ok(())
}

fn bench_diff(args: &Args) -> Result<()> {
    const SUBUSAGE: &str =
        "usage: ocs bench diff OLD NEW [--threshold R] [--summary PATH] [--allow-regression]";
    let old_path =
        std::path::Path::new(args.positional.get(1).map(String::as_str).context(SUBUSAGE)?);
    let new_path =
        std::path::Path::new(args.positional.get(2).map(String::as_str).context(SUBUSAGE)?);
    let old = BenchRecord::load(old_path)?;
    let new = BenchRecord::load(new_path)?;
    let threshold: f64 = args.parse_or("threshold", 0.25)?;
    let d = ocs::bench_record::diff::diff(&old, &new, threshold)?;
    print!("{}", d.table());
    if let Some(summary) = args.str("summary") {
        use std::io::Write as _;
        let mut f = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(summary)
            .with_context(|| format!("open summary file {summary}"))?;
        f.write_all(d.markdown().as_bytes())
            .with_context(|| format!("append to summary file {summary}"))?;
    }
    if d.has_regressions() && !args.bool_or("allow-regression", false) {
        bail!(
            "{} case(s) regressed past the {:.0}% noise threshold",
            d.regressions().count(),
            threshold * 100.0
        );
    }
    Ok(())
}

fn cmd_serve(args: &Args, artifacts: &str) -> Result<()> {
    let requests: usize = args.parse_or("requests", 512)?;
    let serve_cfg = ocs::pipeline::ServeConfig::from_args(args)?;
    if args.bool_or("loadtest", false) {
        return cmd_loadtest(args, artifacts, &serve_cfg, requests);
    }
    let mut sweep = Vec::new();
    for s in args.list("sweep") {
        match s.parse::<usize>() {
            Ok(w) => sweep.push(w),
            Err(_) => bail!("--sweep: cannot parse '{s}' as a worker count"),
        }
    }
    let json_out = args.str("json").map(std::path::PathBuf::from);
    let (factory, cache) = serve_factory(args, artifacts, serve_cfg.max_batch)?;
    // --fault wraps whatever backend was picked in the deterministic
    // failure schedule (a no-op when no --fault is given)
    let factory = ocs::serve::faults::FaultPlan::from_args(args)?.wrap(factory);
    ocs::serve::self_test_with(factory, &serve_cfg, requests, &sweep, json_out.as_deref())?;
    if let Some(cache) = cache {
        println!("{}", cache.stats_line());
    }
    Ok(())
}

/// Parse a comma-separated numeric flag, falling back to `default`
/// when the flag is absent.
fn parse_num_list<T: std::str::FromStr>(args: &Args, flag: &str, default: &[T]) -> Result<Vec<T>>
where
    T: Copy,
{
    let items = args.list(flag);
    if items.is_empty() {
        return Ok(default.to_vec());
    }
    items
        .iter()
        .map(|s| {
            s.parse::<T>()
                .map_err(|_| anyhow::anyhow!("--{flag}: cannot parse '{s}'"))
        })
        .collect()
}

/// `ocs autotune`: budgeted mixed-precision recipe search over the
/// per-layer recipe space on the native backend. Emits the winning
/// `[[quant.layer]]` TOML (`--out`, servable via `ocs serve --recipe`)
/// and a versioned BENCH_autotune.json journal (`--json`).
fn cmd_autotune(args: &Args, artifacts: &str) -> Result<()> {
    match ServeBackend::from_args(args)? {
        ServeBackend::Native => {}
        _ => bail!("autotune scores candidates on the native integer backend (--backend native)"),
    }
    let (spec, ws) = if args.bool_or("sim-free", false) {
        ocs::runtime::native::synthetic_mlp(2027)
    } else {
        let name = args.req("model")?;
        let spec = ModelSpec::load_named(artifacts, name)?;
        let (ws, trained) = WeightStore::load_best(&spec)?;
        if !trained {
            ocs::warnln!("no trained weights for {name}; tuning the init seed");
        }
        (spec, ws)
    };
    if spec.is_lm() {
        bail!("autotune scores CNN models (the LSTM LM is artifact-only)");
    }
    let backend_label = format!("native:{}", spec.name);

    let ladder = parse_num_list::<u32>(args, "ladder", &[8, 6, 5, 4])?;
    let a_bits = parse_num_list::<u32>(args, "a-bits", &[8])?;
    let mut clips = Vec::new();
    for s in args.list("clips") {
        clips.push(ClipMethod::parse(&s).with_context(|| format!("--clips: bad method '{s}'"))?);
    }
    if clips.is_empty() {
        clips = vec![ClipMethod::None, ClipMethod::Mse];
    }
    let a_clip = ClipMethod::parse(args.str_or("a-clip", "mse")).context("bad --a-clip")?;
    let ocs_ratios = parse_num_list::<f64>(args, "ocs-ratios", &[0.0, 0.02, 0.05])?;
    let groups = match args.str_or("group-by", "layer") {
        "layer" => autotune::SearchSpace::per_layer(&spec),
        "kind" => autotune::SearchSpace::by_kind(&spec),
        other => bail!("bad --group-by '{other}' (layer|kind)"),
    };
    let space = autotune::SearchSpace {
        ladder,
        a_bits,
        clips,
        a_clip,
        ocs_ratios,
        allow_skip: args.bool_or("allow-skip", false),
        groups,
    };
    space.validate()?;

    let scorer_cfg = autotune::ScorerCfg {
        calib_images: args.parse_or("calib", 256)?,
        calib_batch: 32,
        test_images: args.parse_or("test", 512)?,
        eval_batch: 128,
        seed: args.parse_or("seed", 29u64)?,
        cache_cap: args.parse_or("cache-cap", 0usize)?,
        gemm_threads: 1,
    };
    let mut scorer = autotune::Scorer::new(spec, ws, scorer_cfg)?;
    let acc_drop: f64 = args.parse_or("acc-drop", 0.02)?;
    let search_cfg = autotune::SearchCfg {
        acc_floor: scorer.float_accuracy - acc_drop,
        footprint_budget: args.parse_opt("footprint-budget")?,
        latency_budget_us: args.parse_opt("latency-budget-us")?,
        beam: args.parse_or("beam", 1usize)?,
        max_evals: args.parse_or("max-evals", 512usize)?,
    };
    println!(
        "autotune: {} group(s) × {} candidate(s)/group, float accuracy {:.2}%, \
         floor {:.2}%, beam {}",
        space.groups.len(),
        space.per_group_candidates(),
        scorer.float_accuracy * 100.0,
        search_cfg.acc_floor * 100.0,
        search_cfg.beam
    );
    let out = autotune::run(&space, &mut scorer, &search_cfg)?;
    println!(
        "autotune: baseline [{}] {:.2}% @ {} B",
        out.baseline.score.label,
        out.baseline.score.accuracy * 100.0,
        out.baseline.score.footprint
    );
    println!(
        "autotune: winner   [{}] {:.2}% @ {} B ({:.0}% of baseline, agreement {:.2}%, \
         ~{:.1} µs/sample modeled)",
        out.winner.score.label,
        out.winner.score.accuracy * 100.0,
        out.winner.score.footprint,
        out.winner.score.footprint as f64 / (out.baseline.score.footprint as f64).max(1.0) * 100.0,
        out.winner.score.agreement * 100.0,
        out.winner.score.est_latency_us
    );
    println!("autotune: {}", space.describe(&out.winner.choices));
    println!(
        "autotune: {} candidate(s) evaluated ({} scored), prep cache {} hit(s) / {} miss(es) \
         / {} eviction(s), {} Pareto point(s)",
        out.evaluated,
        out.scored_total,
        out.cache_hits,
        out.cache_misses,
        out.cache_evictions,
        out.pareto.len()
    );

    let out_path = args.str_or("out", "recipe_autotuned.toml");
    let toml = format!(
        "# emitted by `ocs autotune` — fingerprint {}\n{}",
        out.winner.score.fingerprint,
        out.winner.recipe.to_toml("quant")
    );
    std::fs::write(out_path, &toml).with_context(|| format!("write {out_path}"))?;
    println!(
        "wrote {out_path} (fingerprint {}) — serve it with \
         `ocs serve --backend native --recipe {out_path}`",
        out.winner.score.fingerprint
    );
    if let Some(json) = args.str("json") {
        BenchRecord::from_autotune(&backend_label, &out)
            .write(std::path::Path::new(json))
            .with_context(|| format!("write {json}"))?;
        println!("wrote {json}");
    }
    Ok(())
}

/// Build the worker-engine factory `ocs serve` was asked for. The
/// native backend also hands back its prepared-model cache so callers
/// can print its stats line after the run.
fn serve_factory(
    args: &Args,
    artifacts: &str,
    max_batch: usize,
) -> Result<(
    Arc<dyn ocs::serve::backend::EngineFactory>,
    Option<Arc<ocs::pipeline::PreparedCache>>,
)> {
    Ok(match ServeBackend::from_args(args)? {
        ServeBackend::Sim => (Arc::new(SimFactory::default()) as _, None),
        ServeBackend::Native => {
            // a8 default: float activations would demote every layer to
            // the f32 body — the int datapath is the point of `native`
            let recipe = serve_recipe(args, 8)?;
            let factory = if args.bool_or("sim-free", false) {
                NativeFactory::synthetic(recipe)?
            } else {
                NativeFactory::from_artifacts(artifacts, args.req("model")?, recipe)?
            };
            // the factory cache inherits the global capacity (set from
            // --prep-cache-cap in run()) at construction
            let cache = factory.cache.clone();
            (Arc::new(factory) as _, Some(cache))
        }
        ServeBackend::Pjrt => (
            Arc::new(PjrtFactory {
                artifacts_dir: artifacts.to_string(),
                model: args.req("model")?.to_string(),
                recipe: serve_recipe(args, 0)?,
                max_batch,
            }) as _,
            None,
        ),
    })
}

/// `ocs serve --loadtest`: closed-loop offered-load sweep over a tenant
/// mix. Fixed worker count (from --workers), client concurrency swept
/// via --clients; every step emits client-side latency percentiles and
/// the run ends with the saturation point plus a versioned
/// BENCH_loadtest.json record (CI's loadtest-smoke job gates on it).
fn cmd_loadtest(
    args: &Args,
    artifacts: &str,
    serve_cfg: &ocs::pipeline::ServeConfig,
    requests: usize,
) -> Result<()> {
    let mut clients = Vec::new();
    for s in args.list("clients") {
        match s.parse::<usize>() {
            Ok(c) if c > 0 => clients.push(c),
            _ => bail!("--clients: cannot parse '{s}' as a client count (need >= 1)"),
        }
    }
    let chaos = args.bool_or("chaos", false);
    let chaos_matrix = args.bool_or("chaos-matrix", false);
    let backend = ServeBackend::from_args(args)?;
    // tenant recipes lower with the backend's activation default, like
    // the pool recipe itself
    let default_a_bits = if backend == ServeBackend::Native { 8 } else { 0 };
    let tenants: Vec<TenantInit> = TenantSpec::from_args(args)?
        .iter()
        .map(|t| TenantInit {
            name: t.name.clone(),
            weight: t.weight,
            recipe: Some(t.to_recipe(default_a_bits)),
        })
        .collect();
    let (factory, cache) = serve_factory(args, artifacts, serve_cfg.max_batch)?;
    if chaos_matrix {
        // the matrix schedules its own faults per scenario; --fault is
        // for the plain sweep
        let json_out = std::path::PathBuf::from(args.str_or("json", "BENCH_chaos_matrix.json"));
        let concurrency = clients
            .first()
            .copied()
            .unwrap_or((serve_cfg.workers * 2).max(4));
        ocs::serve::chaos_matrix(
            factory,
            serve_cfg,
            &tenants,
            concurrency,
            requests,
            Some(&json_out),
        )?;
    } else if chaos {
        // the chaos gate schedules its own worker kill; --fault is for
        // the plain sweep
        let json_out = std::path::PathBuf::from(args.str_or("json", "BENCH_chaos.json"));
        let concurrency = clients
            .first()
            .copied()
            .unwrap_or((serve_cfg.workers * 2).max(4));
        ocs::serve::chaos_loadtest(
            factory,
            serve_cfg,
            &tenants,
            concurrency,
            requests,
            Some(&json_out),
        )?;
    } else if args.bool_or("slow-drill", false) {
        // the drill arms its own slow fault; --fault is for the plain sweep
        let json_out = std::path::PathBuf::from(args.str_or("json", "BENCH_slow.json"));
        let slow_us: u64 = args.parse_or("slow-us", 10_000)?;
        let concurrency = clients
            .first()
            .copied()
            .unwrap_or((serve_cfg.workers * 4).max(8));
        ocs::serve::slow_loadtest(
            factory,
            serve_cfg,
            &tenants,
            concurrency,
            requests,
            slow_us,
            Some(&json_out),
        )?;
    } else {
        let json_out = std::path::PathBuf::from(args.str_or("json", "BENCH_loadtest.json"));
        let factory = ocs::serve::faults::FaultPlan::from_args(args)?.wrap(factory);
        ocs::serve::loadtest(
            factory,
            serve_cfg,
            &tenants,
            &clients,
            requests,
            Some(&json_out),
        )?;
    }
    if let Some(cache) = cache {
        println!("{}", cache.stats_line());
    }
    Ok(())
}
