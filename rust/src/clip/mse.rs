//! MSE-optimal clipping (paper §4.1; Sung et al. 2015, Shin et al. 2016).
//!
//! Sweeps candidate thresholds evenly spaced in (0, max|x|] and keeps the
//! one minimizing expected quantization MSE over the histogram
//! (Eq. 9). `CANDIDATES` matches the granularity the reference
//! implementations use; the sweep is O(bins * candidates).

use crate::quant::error::hist_quant_mse;
use crate::quant::QuantSpec;
use crate::stats::Histogram;

pub const CANDIDATES: usize = 128;

pub fn threshold(hist: &Histogram, spec: QuantSpec) -> f32 {
    threshold_with(hist, spec, CANDIDATES)
}

pub fn threshold_with(hist: &Histogram, spec: QuantSpec, candidates: usize) -> f32 {
    let max = hist.max_abs();
    if max <= 0.0 {
        return 0.0;
    }
    let mut best_t = max;
    let mut best_err = f64::INFINITY;
    for k in 1..=candidates {
        let t = max * k as f32 / candidates as f32;
        let err = hist_quant_mse(hist, t, spec);
        if err < best_err {
            best_err = err;
            best_t = t;
        }
    }
    best_t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn no_outliers_high_bits_keeps_near_full_range() {
        // uniform-ish data at 8 bits: clipping gains nothing
        let data: Vec<f32> = (0..4096).map(|i| (i as f32 / 4096.0) * 2.0 - 1.0).collect();
        let hist = Histogram::from_slice(&data, 2048);
        let t = threshold(&hist, QuantSpec::new(8));
        assert!(t > 0.9 * hist.max_abs(), "t {t}");
    }

    #[test]
    fn outliers_at_low_bits_get_clipped() {
        let mut rng = Rng::new(5);
        let mut data: Vec<f32> = (0..50_000).map(|_| rng.normal()).collect();
        data.push(50.0);
        let hist = Histogram::from_slice(&data, 2048);
        let t = threshold(&hist, QuantSpec::new(4));
        assert!(t < 10.0, "t {t} should clip far below the 50.0 outlier");
        assert!(t > 1.0, "t {t} should not clip into the body");
    }

    #[test]
    fn chosen_threshold_is_sweep_argmin() {
        let mut rng = Rng::new(6);
        let data: Vec<f32> = (0..20_000).map(|_| rng.laplace(1.0)).collect();
        let hist = Histogram::from_slice(&data, 2048);
        let spec = QuantSpec::new(5);
        let t = threshold(&hist, spec);
        let err_t = hist_quant_mse(&hist, t, spec);
        for k in [0.25f32, 0.5, 0.75, 1.0] {
            let other = hist.max_abs() * k;
            assert!(err_t <= hist_quant_mse(&hist, other, spec) + 1e-12);
        }
    }
}
