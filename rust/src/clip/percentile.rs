//! Percentile clipping (McKinstry et al. 2018 — paper §2.1's survey;
//! included as the extension method in our sweeps).
//!
//! Threshold = the p-th percentile of |x|, with a bitwidth-dependent
//! default schedule (lower precision clips more aggressively).

use crate::quant::QuantSpec;
use crate::stats::Histogram;

/// McKinstry-style default percentile per bitwidth.
pub fn default_percentile(bits: u32) -> f64 {
    match bits {
        8.. => 0.9999,
        7 => 0.9995,
        6 => 0.999,
        5 => 0.995,
        4 => 0.99,
        _ => 0.98,
    }
}

pub fn threshold(hist: &Histogram, spec: QuantSpec, p: f64) -> f32 {
    let p = if p <= 0.0 { default_percentile(spec.bits) } else { p };
    hist.percentile_abs(p)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn default_schedule_monotone() {
        let mut last = 1.0;
        for bits in (2..=8).rev() {
            let p = default_percentile(bits);
            assert!(p <= last);
            last = p;
        }
    }

    #[test]
    fn percentile_threshold_excludes_tail() {
        let mut rng = Rng::new(10);
        let mut data: Vec<f32> = (0..10_000).map(|_| rng.normal()).collect();
        data.push(100.0);
        let hist = Histogram::from_slice(&data, 2048);
        let t = threshold(&hist, QuantSpec::new(4), 0.99);
        assert!(t < 5.0, "t {t}");
        // p=0 uses the bit default
        let td = threshold(&hist, QuantSpec::new(4), 0.0);
        assert!(td < 10.0 && td > 0.0);
    }
}
