//! KL-divergence calibration (paper §4.3; TensorRT via the MXNet
//! open-source implementation the paper adapted).
//!
//! For each candidate bin count `i` (threshold `T = i * bin_width`):
//!   1. reference P = hist[0..i] with the clipped tail mass folded into
//!      the last bin;
//!   2. quantized Q = the *unfolded* hist[0..i] downsampled to `levels`
//!      groups, each group's mass spread uniformly over its *nonzero*
//!      source bins (MXNet's smoothing). Folding the tail into P but not
//!      Q is what penalizes aggressive clipping — with the tail folded
//!      into both, `i = levels` would always give KL = 0;
//!   3. zero bins of P/Q get epsilon mass;
//!   4. pick the `i` minimizing KL(P || Q).
//!
//! `levels` is the positive-side grid count `qmax + 1` (our grids are
//! sign-magnitude over |x|; MXNet's 255-bin int8 variant corresponds to
//! the same choice for k = 8).

use crate::quant::QuantSpec;
use crate::stats::Histogram;

const EPS: f64 = 1e-10;

/// Sweep stride: checking every bin like MXNet is O(bins^2); stride 4
/// over 2048 bins keeps threshold resolution at 0.2% of range while
/// cutting the sweep 4x (validated against stride-1 in tests).
pub const STRIDE: usize = 4;

fn kl_divergence(p: &[f64], q: &[f64]) -> f64 {
    let ps: f64 = p.iter().sum();
    let qs: f64 = q.iter().sum();
    if ps <= 0.0 || qs <= 0.0 {
        return f64::INFINITY;
    }
    let mut kl = 0.0;
    for (&pi, &qi) in p.iter().zip(q) {
        let pn = pi / ps;
        if pn > 0.0 {
            kl += pn * (pn / (qi / qs).max(EPS)).ln();
        }
    }
    kl
}

/// Build the quantized (downsampled + smoothed) distribution for the
/// first `i` bins collapsed onto `levels` groups.
fn quantize_hist(p: &[f64], levels: usize) -> Vec<f64> {
    let n = p.len();
    let mut q = vec![0.0f64; n];
    if levels == 0 || n == 0 {
        return q;
    }
    let group = (n as f64 / levels as f64).max(1.0);
    for g in 0..levels {
        let start = (g as f64 * group) as usize;
        let stop = (((g + 1) as f64 * group) as usize).min(n);
        if start >= stop {
            continue;
        }
        let mass: f64 = p[start..stop].iter().sum();
        let nonzero = p[start..stop].iter().filter(|&&v| v > 0.0).count();
        if nonzero == 0 {
            continue;
        }
        let share = mass / nonzero as f64;
        for j in start..stop {
            if p[j] > 0.0 {
                q[j] = share;
            }
        }
    }
    q
}

pub fn threshold(hist: &Histogram, spec: QuantSpec) -> f32 {
    threshold_with(hist, spec, STRIDE)
}

pub fn threshold_with(hist: &Histogram, spec: QuantSpec, stride: usize) -> f32 {
    let counts = hist.counts();
    let bins = counts.len();
    let levels = spec.qmax() as usize + 1;
    if hist.count() == 0 {
        return 0.0;
    }
    // useful range: bins up to the max observed magnitude
    let used_bins = ((hist.max_abs() / hist.bin_width()).ceil() as usize).clamp(1, bins);
    if used_bins <= levels {
        return hist.max_abs();
    }
    let total: Vec<f64> = counts.iter().map(|&c| c as f64).collect();
    let mut best = (f64::INFINITY, used_bins);
    let mut i = levels;
    while i <= used_bins {
        // reference: first i bins, tail folded into bin i-1
        let mut p: Vec<f64> = total[..i].to_vec();
        let tail: f64 = total[i..].iter().sum();
        p[i - 1] += tail;
        // smooth zero bins of the reference like MXNet does
        let zeros = p.iter().filter(|&&v| v == 0.0).count();
        if zeros > 0 && zeros < p.len() {
            let eps_total = EPS * zeros as f64;
            let nz = p.len() - zeros;
            for v in p.iter_mut() {
                if *v == 0.0 {
                    *v = EPS;
                } else {
                    *v -= eps_total / nz as f64;
                }
            }
        }
        // candidate: quantize the *unfolded* in-range histogram
        let q = quantize_hist(&total[..i], levels);
        let kl = kl_divergence(&p, &q);
        if kl < best.0 {
            best = (kl, i);
        }
        i += stride.max(1);
    }
    best.1 as f32 * hist.bin_width()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn kl_zero_for_identical() {
        let p = vec![0.25, 0.25, 0.5];
        assert!(kl_divergence(&p, &p).abs() < 1e-12);
    }

    #[test]
    fn kl_positive_for_different() {
        assert!(kl_divergence(&[1.0, 0.0], &[0.5, 0.5]) > 0.1);
    }

    #[test]
    fn quantize_hist_preserves_mass() {
        let p = vec![1.0, 2.0, 0.0, 3.0, 4.0, 0.0, 0.0, 6.0];
        let q = quantize_hist(&p, 2);
        let ps: f64 = p.iter().sum();
        let qs: f64 = q.iter().sum();
        assert!((ps - qs).abs() < 1e-9);
        // zero source bins stay zero (mass spread over nonzero only)
        assert_eq!(q[2], 0.0);
        assert_eq!(q[5], 0.0);
    }

    #[test]
    fn clips_heavy_tail_at_low_bits() {
        let mut rng = Rng::new(8);
        let mut data: Vec<f32> = (0..60_000).map(|_| rng.laplace(1.0)).collect();
        for _ in 0..20 {
            data.push(rng.range_f32(15.0, 20.0));
        }
        let hist = Histogram::from_slice(&data, 2048);
        let t = threshold(&hist, QuantSpec::new(4));
        assert!(t < 12.0, "t {t} should clip below the outlier band");
        assert!(t > 2.0, "t {t} should keep the body");
    }

    #[test]
    fn stride_4_close_to_stride_1() {
        let mut rng = Rng::new(9);
        let data: Vec<f32> = (0..40_000).map(|_| rng.normal()).collect();
        let hist = Histogram::from_slice(&data, 2048);
        let spec = QuantSpec::new(5);
        let t1 = threshold_with(&hist, spec, 1);
        let t4 = threshold_with(&hist, spec, 4);
        assert!(
            (t1 - t4).abs() / t1 < 0.05,
            "stride drift too large: {t1} vs {t4}"
        );
    }

    #[test]
    fn strided_sweep_tracks_exhaustive_on_small_bin_counts() {
        // guards the O(bins^2) -> stride-4 shortcut: on small bin counts
        // the strided argmin must land within one stride of the
        // exhaustive argmin, i.e. the chosen thresholds differ by at
        // most STRIDE bins' worth of magnitude
        for (seed, bins) in [(31u64, 96usize), (32, 160), (33, 256)] {
            let mut rng = Rng::new(seed);
            let data: Vec<f32> = (0..30_000).map(|_| rng.normal()).collect();
            let hist = Histogram::from_slice(&data, bins);
            for bits in [4u32, 5] {
                let spec = QuantSpec::new(bits);
                let exhaustive = threshold_with(&hist, spec, 1);
                let strided = threshold_with(&hist, spec, STRIDE);
                let tol = STRIDE as f32 * hist.bin_width();
                let diff = (exhaustive - strided).abs();
                assert!(
                    diff <= tol + 1e-6 || diff / exhaustive.max(1e-9) < 0.05,
                    "bins {bins} bits {bits}: exhaustive {exhaustive} vs \
                     strided {strided} (tol {tol})"
                );
            }
        }
    }

    #[test]
    fn narrow_hist_returns_max() {
        // fewer used bins than quantization levels: nothing to optimize
        let data = vec![0.1f32, 0.2, 0.3];
        let hist = Histogram::from_slice(&data, 64);
        let t = threshold(&hist, QuantSpec::new(8));
        assert_eq!(t, hist.max_abs());
    }
}
