//! ACIQ analytical clipping (paper §4.2; Banner et al. 2018).
//!
//! Fits both a Gaussian and a Laplacian to the observed moments, picks
//! the better-fitting family (L2 distance between the fitted density and
//! the empirical histogram), then minimizes the *analytic* expected MSE
//!
//! ```text
//! MSE(T) = 2 * clip_tail(T) + delta(T)^2 / 12,   delta = T / qmax
//! ```
//!
//! over T — closed-form tails, no histogram sweep (this is why ACIQ is
//! cheap enough to re-run per activation batch). Following the paper
//! (§4.2) the grid is adjusted to `2^k - 1` sign-magnitude levels, so the
//! in-range noise term uses `delta = T / qmax` rather than ACIQ's
//! original `2T / 2^k`.

use crate::quant::QuantSpec;
use crate::stats::Histogram;

/// Gaussian tail integral: ∫_T^∞ (x-T)^2 N(x; 0, sigma^2) dx
///   = (sigma^2 + T^2) * Phi_c(T/sigma) - T * sigma * phi(T/sigma)
fn gauss_clip_tail(t: f64, sigma: f64) -> f64 {
    if sigma <= 0.0 {
        return 0.0;
    }
    let z = t / sigma;
    let phi = (-0.5 * z * z).exp() / (2.0 * std::f64::consts::PI).sqrt();
    let phic = 0.5 * erfc(z / std::f64::consts::SQRT_2);
    (sigma * sigma + t * t) * phic - t * sigma * phi
}

/// Laplace tail integral: ∫_T^∞ (x-T)^2 Lap(x; 0, b) dx = b^2 e^{-T/b}
fn laplace_clip_tail(t: f64, b: f64) -> f64 {
    if b <= 0.0 {
        return 0.0;
    }
    b * b * (-t / b).exp()
}

/// Complementary error function (Abramowitz & Stegun 7.1.26, |eps|<1.5e-7).
pub fn erfc(x: f64) -> f64 {
    let sign_neg = x < 0.0;
    let x_abs = x.abs();
    let t = 1.0 / (1.0 + 0.3275911 * x_abs);
    let poly = t
        * (0.254829592
            + t * (-0.284496736 + t * (1.421413741 + t * (-1.453152027 + t * 1.061405429))));
    let e = poly * (-x_abs * x_abs).exp();
    if sign_neg {
        2.0 - e
    } else {
        e
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Family {
    Gaussian,
    Laplace,
}

/// L2 distance between the empirical bin masses and the fitted family's
/// predicted masses — the "which fits better" test.
fn fit_distance(hist: &Histogram, family: Family) -> f64 {
    let n = hist.count() as f64;
    if n == 0.0 {
        return f64::INFINITY;
    }
    let sigma = hist.std();
    let b = hist.mean_abs();
    let w = hist.bin_width() as f64;
    let mut d2 = 0.0;
    for (i, &c) in hist.counts().iter().enumerate() {
        let x = hist.bin_center(i) as f64;
        // density of |X| (folded distribution, zero-centred)
        let pdf = match family {
            Family::Gaussian => {
                if sigma <= 0.0 {
                    0.0
                } else {
                    2.0 * (-0.5 * (x / sigma) * (x / sigma)).exp()
                        / (sigma * (2.0 * std::f64::consts::PI).sqrt())
                }
            }
            Family::Laplace => {
                if b <= 0.0 {
                    0.0
                } else {
                    (-x / b).exp() / b
                }
            }
        };
        let expected = pdf * w;
        let got = c as f64 / n;
        d2 += (expected - got) * (expected - got);
    }
    d2
}

pub fn pick_family(hist: &Histogram) -> Family {
    if fit_distance(hist, Family::Gaussian) <= fit_distance(hist, Family::Laplace) {
        Family::Gaussian
    } else {
        Family::Laplace
    }
}

/// Analytic expected MSE for threshold `t` under the fitted family.
fn analytic_mse(t: f64, family: Family, sigma: f64, b: f64, qmax: f64) -> f64 {
    let clip = match family {
        Family::Gaussian => 2.0 * gauss_clip_tail(t, sigma),
        Family::Laplace => 2.0 * laplace_clip_tail(t, b),
    };
    let delta = t / qmax;
    clip + delta * delta / 12.0
}

pub fn threshold(hist: &Histogram, spec: QuantSpec) -> f32 {
    let sigma = hist.std();
    let b = hist.mean_abs();
    if sigma <= 0.0 && b <= 0.0 {
        return hist.max_abs();
    }
    let family = pick_family(hist);
    let scale = match family {
        Family::Gaussian => sigma,
        Family::Laplace => b,
    };
    // golden-section over T in [0.5*scale, alpha_hi*scale]; MSE(T) is
    // unimodal for both families.
    let qmax = spec.qmax() as f64;
    let f = |t: f64| analytic_mse(t, family, sigma, b, qmax);
    let (mut lo, mut hi) = (0.25 * scale, 32.0 * scale);
    let inv_phi = (5.0f64.sqrt() - 1.0) / 2.0;
    let mut c = hi - inv_phi * (hi - lo);
    let mut d = lo + inv_phi * (hi - lo);
    let (mut fc, mut fd) = (f(c), f(d));
    for _ in 0..80 {
        if fc < fd {
            hi = d;
            d = c;
            fd = fc;
            c = hi - inv_phi * (hi - lo);
            fc = f(c);
        } else {
            lo = c;
            c = d;
            fc = fd;
            d = lo + inv_phi * (hi - lo);
            fd = f(d);
        }
    }
    (0.5 * (lo + hi)) as f32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn erfc_reference_values() {
        assert!((erfc(0.0) - 1.0).abs() < 1e-7);
        assert!((erfc(1.0) - 0.15729921).abs() < 1e-6);
        assert!((erfc(-1.0) - 1.84270079).abs() < 1e-6);
        assert!(erfc(5.0) < 2e-12);
    }

    #[test]
    fn family_detection() {
        let mut rng = Rng::new(1);
        let g: Vec<f32> = (0..60_000).map(|_| rng.normal()).collect();
        let l: Vec<f32> = (0..60_000).map(|_| rng.laplace(1.0)).collect();
        assert_eq!(pick_family(&Histogram::from_slice(&g, 2048)), Family::Gaussian);
        assert_eq!(pick_family(&Histogram::from_slice(&l, 2048)), Family::Laplace);
    }

    #[test]
    fn threshold_scales_with_sigma() {
        let mut rng = Rng::new(2);
        let spec = QuantSpec::new(4);
        let a: Vec<f32> = (0..40_000).map(|_| rng.normal()).collect();
        let b: Vec<f32> = a.iter().map(|v| v * 3.0).collect();
        let ta = threshold(&Histogram::from_slice(&a, 2048), spec);
        let tb = threshold(&Histogram::from_slice(&b, 2048), spec);
        assert!((tb / ta - 3.0).abs() < 0.15, "ta {ta} tb {tb}");
    }

    #[test]
    fn threshold_grows_with_bits() {
        // more bits -> cheaper in-range noise -> wider optimal clip
        let mut rng = Rng::new(3);
        let data: Vec<f32> = (0..40_000).map(|_| rng.normal()).collect();
        let hist = Histogram::from_slice(&data, 2048);
        let t4 = threshold(&hist, QuantSpec::new(4));
        let t8 = threshold(&hist, QuantSpec::new(8));
        assert!(t8 > t4, "t4 {t4} t8 {t8}");
        // classic ACIQ alphas are ~2.83 (4b) and ~5.0+ (8b) sigmas for a
        // Gaussian; allow slack for the 2^k-1 grid adjustment.
        // (analytic optimum for sigma=1 is ~2.8 at 4b, ~4.1 at 8b on the
        // 2^k - 1 grid: the in-range noise term delta^2/12 stops paying
        // for wider clips sooner than the 2^k-grid alphas suggest)
        assert!((2.0..4.0).contains(&t4), "t4 {t4}");
        assert!((3.2..8.0).contains(&t8), "t8 {t8}");
    }

    #[test]
    fn golden_section_matches_dense_sweep() {
        let sigma = 1.0;
        let qmax = QuantSpec::new(4).qmax() as f64;
        let f = |t: f64| analytic_mse(t, Family::Gaussian, sigma, 0.8, qmax);
        let t_gs = {
            let mut rng = Rng::new(4);
            let data: Vec<f32> = (0..80_000).map(|_| rng.normal()).collect();
            threshold(&Histogram::from_slice(&data, 2048), QuantSpec::new(4)) as f64
        };
        let mut best = (f64::INFINITY, 0.0);
        let mut t = 0.25;
        while t < 32.0 {
            let v = f(t);
            if v < best.0 {
                best = (v, t);
            }
            t += 0.001;
        }
        assert!((t_gs - best.1).abs() < 0.25, "gs {t_gs} sweep {}", best.1);
    }
}
