//! Clip-threshold optimizers — the paper's §4 survey, reimplemented.
//!
//! Every method consumes a magnitude [`Histogram`] and a bitwidth and
//! returns the clip threshold `T`; linear quantization then uses the grid
//! `delta = T / qmax`. Methods:
//!
//! | Method       | Source                              | Module        |
//! |--------------|-------------------------------------|---------------|
//! | `None`       | plain max-abs (Eq. 1)               | here          |
//! | `Mse`        | Sung/Shin L2 sweep (§4.1)           | [`mse`]       |
//! | `Aciq`       | Banner et al. analytic (§4.2)       | [`aciq`]      |
//! | `Kl`         | TensorRT/MXNet KL calibration (§4.3)| [`kl`]        |
//! | `Percentile` | McKinstry et al. (§2.1, extension)  | [`percentile`]|

pub mod aciq;
pub mod kl;
pub mod mse;
pub mod percentile;

use crate::quant::QuantSpec;
use crate::stats::Histogram;

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ClipMethod {
    /// No clipping: threshold = max |x| (the paper's "Clip - None").
    None,
    /// Minimize expected MSE by sweeping candidate thresholds.
    Mse,
    /// ACIQ: fit Gaussian/Laplace, analytically optimal threshold.
    Aciq,
    /// Minimize KL divergence between float and quantized histograms.
    Kl,
    /// Fixed percentile of the magnitude distribution.
    Percentile(f64),
}

pub const ALL_PAPER_METHODS: [ClipMethod; 4] =
    [ClipMethod::None, ClipMethod::Mse, ClipMethod::Aciq, ClipMethod::Kl];

impl ClipMethod {
    pub fn parse(s: &str) -> Option<ClipMethod> {
        match s {
            "none" => Some(ClipMethod::None),
            "mse" => Some(ClipMethod::Mse),
            "aciq" => Some(ClipMethod::Aciq),
            "kl" => Some(ClipMethod::Kl),
            "percentile" => Some(ClipMethod::Percentile(0.999)),
            s if s.starts_with("percentile:") => s["percentile:".len()..]
                .parse()
                .ok()
                .map(ClipMethod::Percentile),
            _ => None,
        }
    }

    pub fn name(&self) -> String {
        match self {
            ClipMethod::None => "none".into(),
            ClipMethod::Mse => "mse".into(),
            ClipMethod::Aciq => "aciq".into(),
            ClipMethod::Kl => "kl".into(),
            ClipMethod::Percentile(p) => format!("percentile:{p}"),
        }
    }

    /// Compute the clip threshold for `spec`-bit quantization of the
    /// distribution summarized by `hist`.
    pub fn threshold(&self, hist: &Histogram, spec: QuantSpec) -> f32 {
        if hist.count() == 0 {
            return 0.0;
        }
        let t = match self {
            ClipMethod::None => hist.max_abs(),
            ClipMethod::Mse => mse::threshold(hist, spec),
            ClipMethod::Aciq => aciq::threshold(hist, spec),
            ClipMethod::Kl => kl::threshold(hist, spec),
            ClipMethod::Percentile(p) => percentile::threshold(hist, spec, *p),
        };
        // never exceed the observed range; never collapse to zero
        t.min(hist.max_abs()).max(hist.max_abs() * 1e-6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn outlier_hist(seed: u64) -> Histogram {
        let mut rng = Rng::new(seed);
        let mut data: Vec<f32> = (0..30_000).map(|_| rng.normal()).collect();
        for _ in 0..30 {
            data.push(rng.range_f32(8.0, 12.0) * if rng.next_f32() < 0.5 { -1.0 } else { 1.0 });
        }
        Histogram::from_slice(&data, 2048)
    }

    #[test]
    fn parse_roundtrip() {
        for m in [
            ClipMethod::None,
            ClipMethod::Mse,
            ClipMethod::Aciq,
            ClipMethod::Kl,
            ClipMethod::Percentile(0.995),
        ] {
            assert_eq!(ClipMethod::parse(&m.name()), Some(m));
        }
        assert_eq!(ClipMethod::parse("bogus"), None);
    }

    #[test]
    fn all_methods_clip_below_max_on_outlier_distribution() {
        let hist = outlier_hist(1);
        let spec = QuantSpec::new(4);
        let max = hist.max_abs();
        for m in [ClipMethod::Mse, ClipMethod::Aciq, ClipMethod::Kl] {
            let t = m.threshold(&hist, spec);
            assert!(
                t < max * 0.9,
                "{}: threshold {t} did not clip below max {max}",
                m.name()
            );
            assert!(t > 0.0);
        }
        assert_eq!(ClipMethod::None.threshold(&hist, spec), max);
    }

    #[test]
    fn clipping_reduces_expected_mse_at_low_bits() {
        // the paper's core premise: at 4 bits clipping beats max-abs
        let hist = outlier_hist(2);
        let spec = QuantSpec::new(4);
        let full = crate::quant::error::hist_quant_mse(&hist, hist.max_abs(), spec);
        for m in [ClipMethod::Mse, ClipMethod::Aciq, ClipMethod::Kl] {
            let t = m.threshold(&hist, spec);
            let clipped = crate::quant::error::hist_quant_mse(&hist, t, spec);
            assert!(
                clipped < full,
                "{}: {clipped} !< {full}",
                m.name()
            );
        }
    }

    #[test]
    fn empty_histogram_is_safe() {
        let hist = Histogram::new(64, 1.0);
        for m in ALL_PAPER_METHODS {
            assert_eq!(m.threshold(&hist, QuantSpec::new(8)), 0.0);
        }
    }
}
