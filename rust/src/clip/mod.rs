//! Clip-threshold optimizers — the paper's §4 survey, reimplemented.
//!
//! Every method consumes a magnitude [`Histogram`] and a bitwidth and
//! returns the clip threshold `T`; linear quantization then uses the grid
//! `delta = T / qmax`. Built-in methods:
//!
//! | Method       | Source                              | Module        |
//! |--------------|-------------------------------------|---------------|
//! | `None`       | plain max-abs (Eq. 1)               | here          |
//! | `Mse`        | Sung/Shin L2 sweep (§4.1)           | [`mse`]       |
//! | `Aciq`       | Banner et al. analytic (§4.2)       | [`aciq`]      |
//! | `Kl`         | TensorRT/MXNet KL calibration (§4.3)| [`kl`]        |
//! | `Percentile` | McKinstry et al. (§2.1, extension)  | [`percentile`]|
//!
//! The built-ins stay a plain enum ([`ClipMethod`]) — cheap to copy,
//! parse, and fingerprint — but the recipe pipeline consumes them
//! through the [`ClipStrategy`] trait, so custom threshold optimizers
//! plug into a [`crate::pipeline::QuantRecipe`] (via [`ClipSpec::custom`])
//! without touching this module. A strategy's [`ClipStrategy::name`] is
//! its identity everywhere: labels, TOML round-trips, and the prepared-
//! model cache fingerprint all key on it, so it must be stable and
//! unique per distinct thresholding behaviour.

pub mod aciq;
pub mod kl;
pub mod mse;
pub mod percentile;

use std::sync::Arc;

use crate::quant::QuantSpec;
use crate::stats::Histogram;

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ClipMethod {
    /// No clipping: threshold = max |x| (the paper's "Clip - None").
    None,
    /// Minimize expected MSE by sweeping candidate thresholds.
    Mse,
    /// ACIQ: fit Gaussian/Laplace, analytically optimal threshold.
    Aciq,
    /// Minimize KL divergence between float and quantized histograms.
    Kl,
    /// Fixed percentile of the magnitude distribution.
    Percentile(f64),
}

pub const ALL_PAPER_METHODS: [ClipMethod; 4] =
    [ClipMethod::None, ClipMethod::Mse, ClipMethod::Aciq, ClipMethod::Kl];

impl ClipMethod {
    pub fn parse(s: &str) -> Option<ClipMethod> {
        match s {
            "none" => Some(ClipMethod::None),
            "mse" => Some(ClipMethod::Mse),
            "aciq" => Some(ClipMethod::Aciq),
            "kl" => Some(ClipMethod::Kl),
            "percentile" => Some(ClipMethod::Percentile(0.999)),
            s if s.starts_with("percentile:") => s["percentile:".len()..]
                .parse()
                .ok()
                .map(ClipMethod::Percentile),
            _ => None,
        }
    }

    pub fn name(&self) -> String {
        match self {
            ClipMethod::None => "none".into(),
            ClipMethod::Mse => "mse".into(),
            ClipMethod::Aciq => "aciq".into(),
            ClipMethod::Kl => "kl".into(),
            ClipMethod::Percentile(p) => format!("percentile:{p}"),
        }
    }

    /// Compute the clip threshold for `spec`-bit quantization of the
    /// distribution summarized by `hist`.
    pub fn threshold(&self, hist: &Histogram, spec: QuantSpec) -> f32 {
        if hist.count() == 0 {
            return 0.0;
        }
        let t = match self {
            ClipMethod::None => hist.max_abs(),
            ClipMethod::Mse => mse::threshold(hist, spec),
            ClipMethod::Aciq => aciq::threshold(hist, spec),
            ClipMethod::Kl => kl::threshold(hist, spec),
            ClipMethod::Percentile(p) => percentile::threshold(hist, spec, *p),
        };
        // never exceed the observed range; never collapse to zero
        t.min(hist.max_abs()).max(hist.max_abs() * 1e-6)
    }
}

/// A clip-threshold optimizer as a behaviour, not an enum variant.
///
/// [`ClipMethod`] implements this, so every built-in lowers to a trait
/// object for free; external optimizers implement it and enter a recipe
/// through [`ClipSpec::custom`]. `name()` is the strategy's durable
/// identity (labels, fingerprints, TOML) — two strategies returning the
/// same name are treated as interchangeable by the prepared-model cache.
pub trait ClipStrategy: Send + Sync {
    /// Stable identifier; for built-ins this round-trips through
    /// [`ClipMethod::parse`].
    fn name(&self) -> String;

    /// Clip threshold for `spec`-bit quantization of the distribution
    /// summarized by `hist`. Implementations should return a value in
    /// `(0, hist.max_abs()]` for non-empty histograms.
    fn threshold(&self, hist: &Histogram, spec: QuantSpec) -> f32;
}

impl ClipStrategy for ClipMethod {
    fn name(&self) -> String {
        ClipMethod::name(self)
    }

    fn threshold(&self, hist: &Histogram, spec: QuantSpec) -> f32 {
        ClipMethod::threshold(self, hist, spec)
    }
}

/// A recipe's clip slot: a built-in [`ClipMethod`] or a plugged-in
/// [`ClipStrategy`]. Equality and identity are by strategy *name*.
#[derive(Clone)]
pub enum ClipSpec {
    Builtin(ClipMethod),
    Custom(Arc<dyn ClipStrategy>),
}

impl ClipSpec {
    pub fn custom(strategy: Arc<dyn ClipStrategy>) -> ClipSpec {
        ClipSpec::Custom(strategy)
    }

    /// Lower to the trait object the pipeline passes actually call.
    pub fn as_strategy(&self) -> &dyn ClipStrategy {
        match self {
            ClipSpec::Builtin(m) => m,
            ClipSpec::Custom(s) => s.as_ref(),
        }
    }

    pub fn name(&self) -> String {
        self.as_strategy().name()
    }

    pub fn threshold(&self, hist: &Histogram, spec: QuantSpec) -> f32 {
        self.as_strategy().threshold(hist, spec)
    }

    /// Parse a built-in strategy name (custom strategies cannot be
    /// parsed from text — they are registered in code).
    pub fn parse(s: &str) -> Option<ClipSpec> {
        ClipMethod::parse(s).map(ClipSpec::Builtin)
    }
}

impl From<ClipMethod> for ClipSpec {
    fn from(m: ClipMethod) -> ClipSpec {
        ClipSpec::Builtin(m)
    }
}

impl PartialEq for ClipSpec {
    fn eq(&self, other: &Self) -> bool {
        self.name() == other.name()
    }
}

impl std::fmt::Debug for ClipSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ClipSpec::Builtin(m) => write!(f, "ClipSpec({})", m.name()),
            ClipSpec::Custom(s) => write!(f, "ClipSpec(custom:{})", s.name()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn outlier_hist(seed: u64) -> Histogram {
        let mut rng = Rng::new(seed);
        let mut data: Vec<f32> = (0..30_000).map(|_| rng.normal()).collect();
        for _ in 0..30 {
            data.push(rng.range_f32(8.0, 12.0) * if rng.next_f32() < 0.5 { -1.0 } else { 1.0 });
        }
        Histogram::from_slice(&data, 2048)
    }

    #[test]
    fn parse_roundtrip() {
        for m in [
            ClipMethod::None,
            ClipMethod::Mse,
            ClipMethod::Aciq,
            ClipMethod::Kl,
            ClipMethod::Percentile(0.995),
        ] {
            assert_eq!(ClipMethod::parse(&m.name()), Some(m));
        }
        assert_eq!(ClipMethod::parse("bogus"), None);
    }

    /// Recipe fingerprints and TOML serialization both rely on
    /// `parse(name()) == id`, including the `percentile:<p>` payload —
    /// checked property-style over arbitrary probabilities (f64 Display
    /// emits the shortest string that parses back exactly).
    #[test]
    fn name_parse_roundtrip_property() {
        crate::miniprop::check("clip name/parse round-trip", |rng| {
            let m = match rng.below(5) {
                0 => ClipMethod::None,
                1 => ClipMethod::Mse,
                2 => ClipMethod::Aciq,
                3 => ClipMethod::Kl,
                _ => ClipMethod::Percentile(rng.next_f64()),
            };
            let name = m.name();
            match ClipMethod::parse(&name) {
                Some(back) if back == m => {}
                other => {
                    return Err(format!("{m:?} -> '{name}' -> {other:?}"));
                }
            }
            // the name must also be stable: re-derived names are equal
            if back_name(&m) != name {
                return Err(format!("unstable name for {m:?}"));
            }
            Ok(())
        });
        // explicit percentile edges the generator may miss
        for p in [0.0, 1.0, 0.999, 0.5e-7, 0.9999999999999999] {
            let m = ClipMethod::Percentile(p);
            assert_eq!(ClipMethod::parse(&m.name()), Some(m), "p = {p}");
        }
        // the bare keyword keeps its documented default payload
        assert_eq!(
            ClipMethod::parse("percentile"),
            Some(ClipMethod::Percentile(0.999))
        );
        assert_eq!(ClipMethod::parse("percentile:"), None);
        assert_eq!(ClipMethod::parse("percentile:zzz"), None);
    }

    fn back_name(m: &ClipMethod) -> String {
        m.name()
    }

    #[test]
    fn clip_spec_lowers_builtin_and_custom() {
        let hist = outlier_hist(3);
        let spec = QuantSpec::new(4);
        // builtin lowering computes the same threshold as the enum
        let b = ClipSpec::from(ClipMethod::Mse);
        assert_eq!(b.threshold(&hist, spec), ClipMethod::Mse.threshold(&hist, spec));
        assert_eq!(b.name(), "mse");
        assert_eq!(b, ClipSpec::parse("mse").unwrap());
        // a custom strategy plugs in without touching clip/
        struct HalfMax;
        impl ClipStrategy for HalfMax {
            fn name(&self) -> String {
                "halfmax".into()
            }
            fn threshold(&self, hist: &Histogram, _spec: QuantSpec) -> f32 {
                hist.max_abs() * 0.5
            }
        }
        let c = ClipSpec::custom(Arc::new(HalfMax));
        assert_eq!(c.threshold(&hist, spec), hist.max_abs() * 0.5);
        assert_eq!(c.name(), "halfmax");
        assert_ne!(c, b);
        assert!(ClipSpec::parse("halfmax").is_none(), "custom names are code-registered");
    }

    #[test]
    fn all_methods_clip_below_max_on_outlier_distribution() {
        let hist = outlier_hist(1);
        let spec = QuantSpec::new(4);
        let max = hist.max_abs();
        for m in [ClipMethod::Mse, ClipMethod::Aciq, ClipMethod::Kl] {
            let t = m.threshold(&hist, spec);
            assert!(
                t < max * 0.9,
                "{}: threshold {t} did not clip below max {max}",
                m.name()
            );
            assert!(t > 0.0);
        }
        assert_eq!(ClipMethod::None.threshold(&hist, spec), max);
    }

    #[test]
    fn clipping_reduces_expected_mse_at_low_bits() {
        // the paper's core premise: at 4 bits clipping beats max-abs
        let hist = outlier_hist(2);
        let spec = QuantSpec::new(4);
        let full = crate::quant::error::hist_quant_mse(&hist, hist.max_abs(), spec);
        for m in [ClipMethod::Mse, ClipMethod::Aciq, ClipMethod::Kl] {
            let t = m.threshold(&hist, spec);
            let clipped = crate::quant::error::hist_quant_mse(&hist, t, spec);
            assert!(
                clipped < full,
                "{}: {clipped} !< {full}",
                m.name()
            );
        }
    }

    #[test]
    fn empty_histogram_is_safe() {
        let hist = Histogram::new(64, 1.0);
        for m in ALL_PAPER_METHODS {
            assert_eq!(m.threshold(&hist, QuantSpec::new(8)), 0.0);
        }
    }
}
