//! Integration tests for the fault-tolerance layer: panic containment
//! (queued jobs fail, clients never hang), supervisor respawn with
//! backoff, breaker give-up, dead-shard rejection at the router,
//! per-tenant fault isolation (siblings stay bit-stable), per-tenant
//! admission quotas, and the chaos loadtest gate — all driven through
//! the deterministic [`FaultPlan`] schedules, so they run in CI on the
//! sim and native backends with no artifacts.

use std::sync::{Arc, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use ocs::clip::ClipMethod;
use ocs::pipeline::{QuantConfig, QuantRecipe, ServeConfig};
use ocs::serve::backend::{NativeFactory, SimFactory};
use ocs::serve::faults::FaultPlan;
use ocs::serve::{chaos_loadtest, Server, TenantInit, TenantTable};
use ocs::tensor::TensorF;

/// Same discipline as `it_serve_pool`: these tests run pools and burn
/// CPU; serialize them so they don't corrupt each other's timing.
static SERIAL: Mutex<()> = Mutex::new(());

fn serial() -> MutexGuard<'static, ()> {
    SERIAL.lock().unwrap_or_else(|e| e.into_inner())
}

/// Pool config with a fast supervisor (1 ms backoff base) so respawn
/// tests finish quickly.
fn cfg(workers: usize) -> ServeConfig {
    ServeConfig {
        workers,
        max_batch: 4,
        max_wait: Duration::from_micros(200),
        queue_cap: 64,
        deadline: None,
        backoff: Duration::from_millis(1),
        ..ServeConfig::default()
    }
}

fn sim() -> Arc<SimFactory> {
    Arc::new(SimFactory::default())
}

fn recipe(w_bits: u32) -> QuantRecipe {
    let mut c = QuantConfig::weights_only(w_bits, ClipMethod::Mse, 0.02);
    c.a_bits = Some(8);
    c.to_recipe()
}

fn tenant(name: &str, weight: f64, r: Option<QuantRecipe>) -> TenantInit {
    TenantInit {
        name: name.into(),
        weight,
        recipe: r,
    }
}

/// One fixed `(1, 16, 16, 3)` image for the synthetic MLP, and a
/// second distinct one for batch variety.
fn image() -> TensorF {
    let ds = ocs::train::data::synth_images(4, 77);
    ocs::calib::slice_rows(&ds.x, 0, 1).unwrap()
}

/// Retry an infer until the pool serves it (the respawn window rejects
/// or fails requests); panics after `secs` seconds of failures.
fn infer_until_ok(client: &ocs::serve::Client, x: &TensorF, secs: u64) -> Vec<f32> {
    let t0 = Instant::now();
    loop {
        match client.infer(x.clone()) {
            Ok(logits) => return logits,
            Err(e) => {
                if t0.elapsed() > Duration::from_secs(secs) {
                    panic!("pool never recovered: last error: {e:#}");
                }
                std::thread::sleep(Duration::from_millis(2));
            }
        }
    }
}

#[test]
fn panic_mid_batch_is_contained_and_the_pool_recovers() {
    let _guard = serial();
    // single worker so the panic's blast radius is the whole pool: the
    // strongest version of "no client hangs"
    let plan = FaultPlan::parse("panic:0@2").unwrap();
    let server = Server::start_with(plan.wrap(sim()), cfg(1)).unwrap();
    let client = server.client();
    let x = image();
    assert!(client.infer(x.clone()).is_ok(), "batch 1 is clean");
    // batch 2 panics: the in-flight job must get an explicit error (not
    // a hang, not a process abort)
    let err = client
        .infer(x.clone())
        .expect_err("the panicked batch's job must fail")
        .to_string();
    assert!(err.contains("panicked"), "{err}");
    // the supervisor respawns worker 0; the one-shot fault is spent, so
    // the replacement serves
    let logits = infer_until_ok(&client, &x, 5);
    assert!(!logits.is_empty());
    let agg = server.metrics().aggregate();
    assert!(agg.panics >= 1, "panic counted: {agg:?}");
    assert!(agg.restarts >= 1, "restart counted: {agg:?}");
    assert_eq!(server.dead_workers(), 0, "no breaker opened");
    // containment means shutdown sees *cleanly exited* threads
    server.shutdown().unwrap();
}

#[test]
fn give_up_opens_the_breaker_and_rejects_cleanly() {
    let _guard = serial();
    let mut c = cfg(1);
    c.restart_max = 0; // never respawn: first death opens the breaker
    let plan = FaultPlan::parse("panic:0@1").unwrap();
    let server = Server::start_with(plan.wrap(sim()), c).unwrap();
    let client = server.client();
    let x = image();
    let err = client
        .infer(x.clone())
        .expect_err("batch 1 panics")
        .to_string();
    assert!(err.contains("panicked"), "{err}");
    // the supervisor gives up; poll until the breaker is visible
    let t0 = Instant::now();
    while server.dead_workers() == 0 {
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "breaker never opened"
        );
        std::thread::sleep(Duration::from_millis(2));
    }
    // Client::infer on the dead shard is a *clean rejection* — the
    // send-to-disconnected-channel path must never unwrap or hang
    let err = client
        .infer(x.clone())
        .expect_err("dead pool must reject")
        .to_string();
    assert!(err.contains("no live workers"), "{err}");
    assert!(server.metrics().rejected_count() >= 1);
    assert!(server.metrics().is_dead(0));
    server.shutdown().unwrap();
}

#[test]
fn respawn_retries_through_a_failing_rebuild() {
    let _guard = serial();
    // death #1: panic on batch 1; the respawn's rebuild (build #2) also
    // fails; the supervisor must burn a second restart and succeed on
    // build #3
    let plan = FaultPlan::parse("panic:0@1,build-fail:0@2").unwrap();
    let server = Server::start_with(plan.wrap(sim()), cfg(1)).unwrap();
    let client = server.client();
    let x = image();
    let _ = client.infer(x.clone()); // trips the panic
    let logits = infer_until_ok(&client, &x, 5);
    assert!(!logits.is_empty());
    let agg = server.metrics().aggregate();
    assert!(agg.restarts >= 2, "panic + rebuild failure: {agg:?}");
    assert_eq!(server.dead_workers(), 0);
    server.shutdown().unwrap();
}

#[test]
fn startup_build_failure_still_fails_the_pool() {
    let _guard = serial();
    // fault injection must not weaken the readiness gate: a worker that
    // cannot build at startup fails Server::start as a whole
    let plan = FaultPlan::parse("build-fail:1@1").unwrap();
    let err = match Server::start_with(plan.wrap(sim()), cfg(2)) {
        Ok(_) => panic!("startup must fail"),
        Err(e) => format!("{e:#}"),
    };
    assert!(err.contains("worker 1 setup"), "{err}");
    assert!(err.contains("fault injection"), "{err}");
}

#[test]
fn tenant_fault_leaves_siblings_bit_stable() {
    let _guard = serial();
    let tenants = [
        tenant("gold", 1.0, Some(QuantConfig::float().to_recipe())),
        tenant("bulk", 1.0, Some(recipe(3))),
    ];
    let x = image();
    // fault-free run: the reference logits
    let clean = Arc::new(NativeFactory::synthetic(recipe(5)).unwrap());
    let server =
        Server::start_tenants(clean, cfg(1), TenantTable::new(&tenants).unwrap()).unwrap();
    let client = server.client();
    let default_ref = client.infer(x.clone()).unwrap();
    let bulk_ref = client.infer_tenant("bulk", x.clone()).unwrap();
    server.shutdown().unwrap();
    // same pool with gold scheduled to error: siblings must be
    // bit-identical to the fault-free run
    let plan = FaultPlan::parse("error-tenant:gold").unwrap();
    let faulty = plan.wrap(Arc::new(NativeFactory::synthetic(recipe(5)).unwrap()));
    let server =
        Server::start_tenants(faulty, cfg(1), TenantTable::new(&tenants).unwrap()).unwrap();
    let client = server.client();
    let err = client
        .infer_tenant("gold", x.clone())
        .expect_err("gold is scheduled to fail")
        .to_string();
    assert!(err.contains("fault injection"), "{err}");
    assert_eq!(client.infer(x.clone()).unwrap(), default_ref);
    assert_eq!(client.infer_tenant("bulk", x.clone()).unwrap(), bulk_ref);
    // tenant errors are survivable: no panic, no restart, no breaker
    let agg = server.metrics().aggregate();
    assert_eq!(agg.panics, 0);
    assert_eq!(agg.restarts, 0);
    assert_eq!(server.dead_workers(), 0);
    server.shutdown().unwrap();
}

#[test]
fn tenant_quota_caps_admission_without_starving_siblings() {
    let _guard = serial();
    // 1 worker × queue_cap 4 × quota 0.5 → each tenant caps at 2
    // queued+in-flight jobs; a slow engine keeps them queued
    let slow = Arc::new(SimFactory {
        classes: 10,
        cost_per_batch: Duration::from_millis(200),
        cost_per_item: Duration::from_millis(1),
    });
    let c = ServeConfig {
        workers: 1,
        max_batch: 1,
        max_wait: Duration::from_micros(100),
        queue_cap: 4,
        deadline: None,
        tenant_quota: Some(0.5),
        ..ServeConfig::default()
    };
    let tenants = [tenant("bulk", 1.0, None)];
    let server = Server::start_tenants(slow, c, TenantTable::new(&tenants).unwrap()).unwrap();
    let bulk_id = server.client().tenant_id("bulk").unwrap();
    let x = image();
    // saturate bulk's share from background threads (each blocks on its
    // response); poll the outstanding gauge until both are admitted
    let mut held = Vec::new();
    for _ in 0..2 {
        let client = server.client();
        let x = x.clone();
        held.push(std::thread::spawn(move || client.infer_tenant("bulk", x)));
    }
    let t0 = Instant::now();
    while server.metrics().tenant_outstanding_count(bulk_id) < 2 {
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "bulk jobs were never admitted"
        );
        std::thread::sleep(Duration::from_millis(2));
    }
    // third bulk submit: over quota, rejected immediately
    let err = server
        .client()
        .infer_tenant("bulk", x.clone())
        .expect_err("over-quota submit must be rejected")
        .to_string();
    assert!(err.contains("over admission quota"), "{err}");
    assert_eq!(server.metrics().tenant_quota_rejected_count(bulk_id), 1);
    // quota rejections are a subset of the tenant's rejections
    assert_eq!(server.metrics().tenant_rejected_count(bulk_id), 1);
    // ...but default's share is untouched: its submit is admitted and
    // served even while bulk is saturated
    let logits = server.client().infer(x.clone()).unwrap();
    assert!(!logits.is_empty(), "sibling starved by bulk's backlog");
    for h in held {
        let _ = h.join().unwrap();
    }
    server.shutdown().unwrap();
}

#[test]
fn chaos_loadtest_survives_a_worker_kill() {
    let _guard = serial();
    // the acceptance gate, in-process: 4 workers, kill one mid-load,
    // assert no hang / bounded errors / recovery (chaos_loadtest bails
    // on any violated invariant)
    let mut c = cfg(4);
    c.queue_cap = 32;
    let report = chaos_loadtest(sim(), &c, &[], 8, 160, None).unwrap();
    assert_eq!(report.killed_worker, 3);
    assert!(report.panics >= 1, "{report:?}");
    assert!(report.restarts >= 1, "{report:?}");
    assert!(report.degraded.ok > 0, "{report:?}");
    assert!(
        report.recovered.rps >= 0.5 * report.healthy.rps,
        "{report:?}"
    );
}
