//! Cross-module determinism guarantees for the kernel layer: every
//! parallel hot path must be bit-identical at `threads = 1` and
//! `threads = N`, including when composed the way `pipeline::prepare`
//! composes them (per-channel quantization fed by calibration stats),
//! and the pool must stay live under nesting and panics.

use ocs::clip::ClipMethod;
use ocs::kernels::stats::layer_stats;
use ocs::kernels::{pool, split_channel};
use ocs::ocs::{weight_ocs, SplitMode};
use ocs::quant::channelwise::fake_quant_per_channel_with;
use ocs::quant::QuantSpec;
use ocs::tensor::TensorF;
use ocs::util::rng::Rng;

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// A weight with heterogeneous channel scales and a couple of planted
/// outliers — the worst case for threshold search determinism.
fn spicy_weight(seed: u64, c: usize, k: usize) -> TensorF {
    let mut rng = Rng::new(seed);
    let mut data = rng.normal_vec(c * k);
    for j in 0..k {
        data[(c / 3) * k + j] *= 9.0;
        data[(2 * c / 3) * k + j] *= 0.1;
    }
    TensorF::from_vec(&[c, k], data).unwrap()
}

#[test]
fn per_channel_quant_is_thread_count_invariant() {
    let w = spicy_weight(1, 96, 40);
    for clip in [ClipMethod::None, ClipMethod::Mse, ClipMethod::Kl] {
        let (q1, t1) = fake_quant_per_channel_with(&w, 0, QuantSpec::new(4), clip, 1);
        for threads in [2usize, 3, 8] {
            let (qn, tn) = fake_quant_per_channel_with(&w, 0, QuantSpec::new(4), clip, threads);
            assert_eq!(bits(q1.data()), bits(qn.data()), "{clip:?} t={threads}");
            assert_eq!(bits(&t1), bits(&tn), "{clip:?} thresholds t={threads}");
        }
    }
    // non-contiguous channel axis too
    let (q1, t1) = fake_quant_per_channel_with(&w, 1, QuantSpec::new(6), ClipMethod::Mse, 1);
    let (qn, tn) = fake_quant_per_channel_with(&w, 1, QuantSpec::new(6), ClipMethod::Mse, 8);
    assert_eq!(bits(q1.data()), bits(qn.data()));
    assert_eq!(bits(&t1), bits(&tn));
}

#[test]
fn calibration_stats_are_thread_count_invariant() {
    let mut rng = Rng::new(2);
    let mut batches = Vec::new();
    for i in 0..7 {
        let mut v = rng.normal_vec(24 * 16);
        v[i] = 30.0 + i as f32; // outliers at shifting spots
        batches.push(TensorF::from_vec(&[24, 16], v).unwrap());
    }
    let s1 = layer_stats(&batches, 2048, 0.99, 1);
    for threads in [2usize, 4, 16] {
        let sn = layer_stats(&batches, 2048, 0.99, threads);
        assert_eq!(s1.hist.counts(), sn.hist.counts(), "t={threads}");
        assert_eq!(s1.hist.count(), sn.hist.count());
        assert_eq!(s1.hist.range().to_bits(), sn.hist.range().to_bits());
        assert_eq!(s1.hist.mean().to_bits(), sn.hist.mean().to_bits());
        assert_eq!(bits(&s1.channel_max), bits(&sn.channel_max));
        assert_eq!(s1.outlier_counts, sn.outlier_counts);
        assert_eq!(
            s1.outlier_threshold.to_bits(),
            sn.outlier_threshold.to_bits()
        );
    }
}

#[test]
fn composed_pipeline_path_is_thread_count_invariant() {
    // calibration -> channel ranking -> per-channel quant, at 1 vs N
    // threads end to end (the shape pipeline::prepare exercises)
    let mut rng = Rng::new(3);
    let batches: Vec<TensorF> = (0..4)
        .map(|_| TensorF::from_vec(&[32, 12], rng.normal_vec(32 * 12)).unwrap())
        .collect();
    let w = spicy_weight(4, 12, 20);
    let run = |threads: usize| -> (Vec<usize>, Vec<u32>) {
        let s = layer_stats(&batches, 512, 0.99, threads);
        let top = ocs::calib::top_k_channels(&s.outlier_counts, 3);
        let spec = QuantSpec::new(5);
        let (q, _) = fake_quant_per_channel_with(&w, 0, spec, ClipMethod::Mse, threads);
        (top, bits(q.data()))
    };
    let serial = run(1);
    for threads in [2usize, 8] {
        assert_eq!(run(threads), serial, "t={threads}");
    }
}

#[test]
fn fused_ocs_split_matches_generic_ops_through_weight_ocs() {
    // weight_ocs (fused kernel inside) against a hand-rolled generic-op
    // split sequence — bit-for-bit, including the greedy channel choice
    let w = spicy_weight(5, 10, 8);
    for mode in [SplitMode::Naive, SplitMode::QuantAware] {
        let hooks = weight_ocs(&w, 0, 14, 4, mode, 0.03).unwrap();
        // reference: replay the same splits with tensor ops
        let mut reference = w.pad_axis(0, 14).unwrap();
        for &(src, dst) in &hooks.splits {
            reference
                .axis_copy_with(0, src, dst, |v| {
                    ocs::ocs::split::split_value(v, 0.03, mode).1
                })
                .unwrap();
            reference
                .axis_map_mut(0, src, |v| *v = ocs::ocs::split::split_value(*v, 0.03, mode).0)
                .unwrap();
        }
        assert_eq!(bits(hooks.w_expanded.data()), bits(reference.data()), "{mode:?}");
    }
}

#[test]
fn split_channel_kernel_direct() {
    let w = spicy_weight(6, 6, 5);
    let mut a = w.pad_axis(0, 8).unwrap();
    let mut b = a.clone();
    let (lo, hi) = split_channel(a.data_mut(), 1, 8, 5, 2, 6, 0.1, SplitMode::QuantAware);
    b.axis_copy_with(0, 2, 6, |v| ocs::ocs::split::split_value(v, 0.1, SplitMode::QuantAware).1)
        .unwrap();
    b.axis_map_mut(0, 2, |v| *v = ocs::ocs::split::split_value(*v, 0.1, SplitMode::QuantAware).0)
        .unwrap();
    assert_eq!(bits(a.data()), bits(b.data()));
    assert_eq!(lo.to_bits(), b.axis_max_abs(0, 2).unwrap().to_bits());
    assert_eq!(hi.to_bits(), b.axis_max_abs(0, 6).unwrap().to_bits());
}

#[test]
fn pool_survives_nesting_and_panics_under_load() {
    // nested maps from pool threads must not deadlock
    let nested = pool::map_indexed_with(4, 5, |i| {
        pool::map_indexed_with(4, 11, move |j| (i * 11 + j) as u64)
            .into_iter()
            .sum::<u64>()
    });
    let expect: Vec<u64> = (0..5)
        .map(|i| (0..11).map(|j| (i * 11 + j) as u64).sum())
        .collect();
    assert_eq!(nested, expect);
    // a panicking kernel propagates and leaves the pool usable
    let caught = std::panic::catch_unwind(|| {
        pool::map_indexed_with(4, 32, |i| {
            if i == 17 {
                panic!("kernel panic under test");
            }
            i
        })
    });
    assert!(caught.is_err());
    let after = pool::map_indexed_with(4, 16, |i| i + 1);
    assert_eq!(after, (1..=16).collect::<Vec<_>>());
}
