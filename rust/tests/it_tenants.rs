//! Integration tests for per-tenant serving: routing + fallback,
//! per-tenant hot-swap isolation under concurrent load, cold-tenant
//! cache eviction while hot tenants keep serving, and the closed-loop
//! load harness emitting a gated bench record — all artifact-free
//! (native synthetic MLP and the sim backend), so they run in CI.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use ocs::bench_record::BenchRecord;
use ocs::clip::ClipMethod;
use ocs::pipeline::{QuantConfig, QuantRecipe, ServeConfig};
use ocs::serve::backend::{NativeFactory, SimFactory};
use ocs::serve::{loadtest, Server, TenantInit, TenantTable};
use ocs::tensor::TensorF;

/// Same discipline as `it_serve_pool`: these tests run pools and burn
/// CPU; serialize them so they don't corrupt each other's timing.
static SERIAL: Mutex<()> = Mutex::new(());

fn serial() -> MutexGuard<'static, ()> {
    SERIAL.lock().unwrap_or_else(|e| e.into_inner())
}

fn cfg(workers: usize) -> ServeConfig {
    ServeConfig {
        workers,
        max_batch: 4,
        max_wait: Duration::from_micros(200),
        queue_cap: 256,
        deadline: None,
        ..ServeConfig::default()
    }
}

/// A serving recipe with observable quantization (logits move with
/// `w_bits`, so tests can see *which* prep served a request).
fn recipe(w_bits: u32) -> QuantRecipe {
    let mut c = QuantConfig::weights_only(w_bits, ClipMethod::Mse, 0.02);
    c.a_bits = Some(8);
    c.to_recipe()
}

fn native() -> Arc<NativeFactory> {
    Arc::new(NativeFactory::synthetic(recipe(5)).unwrap())
}

fn tenant(name: &str, weight: f64, r: Option<QuantRecipe>) -> TenantInit {
    TenantInit {
        name: name.into(),
        weight,
        recipe: r,
    }
}

/// One fixed `(1, 16, 16, 3)` image for the synthetic MLP.
fn image() -> TensorF {
    let ds = ocs::train::data::synth_images(4, 77);
    ocs::calib::slice_rows(&ds.x, 0, 1).unwrap()
}

#[test]
fn unknown_tenant_falls_back_to_default() {
    let _guard = serial();
    let tenants = [tenant("gold", 1.0, Some(QuantConfig::float().to_recipe()))];
    let server =
        Server::start_tenants(native(), cfg(1), TenantTable::new(&tenants).unwrap()).unwrap();
    let client = server.client();
    let x = image();
    let default = client.infer(x.clone()).unwrap();
    let gold = client.infer_tenant("gold", x.clone()).unwrap();
    assert_ne!(default, gold, "tenant recipes must be observable");
    // a tenant nobody configured serves the default recipe, not an error
    let ghost = client.infer_tenant("ghost", x.clone()).unwrap();
    assert_eq!(ghost, default, "unknown tenant must serve the default prep");
    assert_eq!(server.metrics().unknown_tenant_count(), 1);
    // ...and the traffic is attributed to tenant 0, not lost
    assert_eq!(server.metrics().tenant(0).snapshot().requests, 2);
    assert_eq!(server.metrics().tenant(1).snapshot().requests, 1);
    server.shutdown().unwrap();
}

#[test]
fn tenant_hot_swap_is_isolated_under_concurrent_load() {
    let _guard = serial();
    let tenants = [
        tenant("gold", 1.0, Some(QuantConfig::float().to_recipe())),
        tenant("bulk", 1.0, Some(recipe(3))),
    ];
    let server =
        Server::start_tenants(native(), cfg(2), TenantTable::new(&tenants).unwrap()).unwrap();
    let x = image();
    let client = server.client();
    let default_expect = client.infer(x.clone()).unwrap();
    let gold_expect = client.infer_tenant("gold", x.clone()).unwrap();
    let bulk_before = client.infer_tenant("bulk", x.clone()).unwrap();
    assert_ne!(gold_expect, bulk_before);
    assert_ne!(gold_expect, default_expect);
    assert_ne!(bulk_before, default_expect);
    // hammer gold + default from client threads while bulk is swapped:
    // their logits must never move
    let stop = Arc::new(AtomicBool::new(false));
    let mut handles = Vec::new();
    for (name, expect) in [("gold", gold_expect.clone()), ("default", default_expect.clone())] {
        let client = server.client();
        let x = x.clone();
        let stop = stop.clone();
        handles.push(std::thread::spawn(move || {
            let mut served = 0usize;
            while !stop.load(Ordering::Relaxed) {
                let got = client.infer_tenant(name, x.clone()).unwrap();
                assert_eq!(got, expect, "tenant {name} drifted during a sibling's swap");
                served += 1;
            }
            served
        }));
    }
    // swap bulk to the float recipe mid-load; float == gold's recipe,
    // so post-swap bulk logits must match gold's bitwise
    server
        .swap_tenant_recipe("bulk", QuantConfig::float().to_recipe())
        .unwrap();
    let t0 = Instant::now();
    loop {
        let got = client.infer_tenant("bulk", x.clone()).unwrap();
        if got == gold_expect {
            break;
        }
        assert_eq!(got, bulk_before, "mid-swap bulk must serve old or new, nothing else");
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "swap never became visible"
        );
        std::thread::sleep(Duration::from_millis(2));
    }
    stop.store(true, Ordering::Relaxed);
    for h in handles {
        assert!(h.join().unwrap() > 0, "load threads must actually serve");
    }
    // swaps have no unknown-tenant fallback: a typo must fail loudly
    let err = server
        .swap_tenant_recipe("ghost", QuantConfig::float().to_recipe())
        .unwrap_err();
    assert!(err.to_string().contains("unknown tenant"), "{err:#}");
    server.shutdown().unwrap();
}

#[test]
fn cold_tenant_eviction_keeps_hot_tenants_serving() {
    let _guard = serial();
    let factory = native();
    // capacity-1 prepared cache: every new tenant prep evicts the
    // previous one, but workers hold their lowered executables, so
    // serving never goes back to the cache
    factory.cache.set_capacity(1);
    let cache = factory.cache.clone();
    let tenants = [
        tenant("gold", 1.0, Some(QuantConfig::float().to_recipe())),
        tenant("bulk", 1.0, Some(recipe(3))),
    ];
    let server =
        Server::start_tenants(factory, cfg(1), TenantTable::new(&tenants).unwrap()).unwrap();
    let client = server.client();
    let x = image();
    let d0 = client.infer(x.clone()).unwrap();
    let g0 = client.infer_tenant("gold", x.clone()).unwrap();
    let b0 = client.infer_tenant("bulk", x.clone()).unwrap();
    assert_eq!(cache.misses(), 3, "one prepare per distinct recipe");
    assert_eq!(cache.len(), 1, "capacity 1 keeps only the newest prep");
    for round in 0..10 {
        assert_eq!(client.infer(x.clone()).unwrap(), d0, "round {round}");
        assert_eq!(client.infer_tenant("gold", x.clone()).unwrap(), g0, "round {round}");
        assert_eq!(client.infer_tenant("bulk", x.clone()).unwrap(), b0, "round {round}");
    }
    assert_eq!(
        cache.misses(),
        3,
        "steady-state serving must not re-prepare evicted tenants"
    );
    server.shutdown().unwrap();
}

#[test]
fn loadtest_emits_a_valid_gated_record() {
    let _guard = serial();
    let dir = std::env::temp_dir().join(format!("ocs_loadtest_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("BENCH_loadtest.json");
    let factory = Arc::new(SimFactory {
        classes: 10,
        cost_per_batch: Duration::from_micros(50),
        cost_per_item: Duration::from_micros(50),
    });
    let tenants = [tenant("gold", 2.0, None)];
    let points = loadtest(factory, &cfg(2), &tenants, &[1, 2], 60, Some(&path)).unwrap();
    assert_eq!(points.len(), 2);
    for p in &points {
        assert_eq!(p.ok, p.requests, "no deadline + bounded clients: all succeed");
        assert!(p.rps > 0.0);
        assert!(p.p50_ms <= p.p95_ms && p.p95_ms <= p.p99_ms);
        assert!(p.mean_ms > 0.0);
        let attributed: u64 = p.tenants.iter().map(|(_, ok, _)| ok).sum();
        assert_eq!(attributed, p.ok as u64, "per-tenant counts cover the pool total");
        assert!(
            p.tenants.iter().any(|(n, ok, _)| n == "gold" && *ok > 0),
            "weight-2 tenant must see traffic: {:?}",
            p.tenants
        );
    }
    let rec = BenchRecord::load(&path).unwrap();
    rec.validate().unwrap();
    assert_eq!(rec.bench, "loadtest");
    let c1 = rec.row("loadtest/c1").unwrap();
    assert!(c1.higher_is_better);
    assert_eq!(c1.unit, "req/s");
    for key in ["p50_ms", "p95_ms", "p99_ms", "tenant_gold_ok", "tenant_default_ok"] {
        assert!(c1.extra.contains_key(key), "missing extra '{key}'");
    }
    let sat = rec.row("loadtest/saturation").unwrap();
    let best = points.iter().map(|p| p.rps).fold(0.0f64, f64::max);
    assert_eq!(sat.value, best, "saturation row carries the peak step");
    std::fs::remove_dir_all(&dir).unwrap();
}
