//! Integration tests for `ocs autotune`: the determinism contract
//! (same seed ⇒ identical winning fingerprint and identical emitted
//! TOML), the capacity-bounded prep cache (evictions change cost, never
//! the winner), the uniform-baseline Pareto claim, the journal schema,
//! and the emit path — the winning TOML must round-trip through the
//! same `[quant]` loader `ocs serve --recipe` and `ocs tables` use.

use ocs::autotune::{run, Scorer, ScorerCfg, SearchCfg, SearchSpace};
use ocs::bench_record::BenchRecord;
use ocs::clip::ClipMethod;
use ocs::pipeline::QuantRecipe;
use ocs::runtime::native::synthetic_mlp;
use ocs::util::toml::Config;

fn scorer(seed: u64, cache_cap: usize) -> Scorer {
    let (spec, ws) = synthetic_mlp(2027);
    let cfg = ScorerCfg {
        calib_images: 64,
        calib_batch: 32,
        test_images: 96,
        eval_batch: 32,
        seed,
        cache_cap,
        gemm_threads: 1,
    };
    Scorer::new(spec, ws, cfg).unwrap()
}

fn space(scorer: &Scorer) -> SearchSpace {
    SearchSpace {
        ladder: vec![8, 4],
        a_bits: vec![8],
        clips: vec![ClipMethod::None, ClipMethod::Mse],
        a_clip: ClipMethod::Mse,
        ocs_ratios: vec![0.0, 0.05],
        allow_skip: true,
        groups: SearchSpace::per_layer(scorer.spec()),
    }
}

fn search_cfg(scorer: &Scorer) -> SearchCfg {
    SearchCfg {
        acc_floor: scorer.float_accuracy - 0.10,
        ..SearchCfg::default()
    }
}

#[test]
fn same_seed_same_winner_and_same_toml() {
    let mut a = scorer(7, 0);
    let sp = space(&a);
    let cfg = search_cfg(&a);
    let out_a = run(&sp, &mut a, &cfg).unwrap();
    let mut b = scorer(7, 0);
    let out_b = run(&sp, &mut b, &cfg).unwrap();
    assert_eq!(
        out_a.winner.score.fingerprint, out_b.winner.score.fingerprint,
        "same seed must replay to the same winner"
    );
    assert_eq!(
        out_a.winner.recipe.to_toml("quant"),
        out_b.winner.recipe.to_toml("quant"),
        "and to byte-identical emitted TOML"
    );
    assert_eq!(out_a.evaluated, out_b.evaluated);
    assert_eq!(out_a.pareto, out_b.pareto);
}

#[test]
fn bounded_cache_evicts_but_keeps_the_winner() {
    let mut unbounded = scorer(7, 0);
    let sp = space(&unbounded);
    let cfg = search_cfg(&unbounded);
    let free = run(&sp, &mut unbounded, &cfg).unwrap();
    assert_eq!(free.cache_evictions, 0, "cap 0 = unbounded");
    // a 2-entry cache must thrash on a multi-candidate search yet land
    // on the identical winner: capacity is a cost knob, not a policy
    let mut bounded = scorer(7, 2);
    let tight = run(&sp, &mut bounded, &cfg).unwrap();
    assert!(
        tight.cache_evictions > 0,
        "cap 2 must evict across {} evals",
        tight.evaluated
    );
    assert_eq!(tight.winner.score.fingerprint, free.winner.score.fingerprint);
    assert_eq!(tight.winner.score.footprint, free.winner.score.footprint);
}

#[test]
fn winner_meets_floor_at_or_below_baseline_footprint() {
    let mut s = scorer(7, 0);
    let sp = space(&s);
    let cfg = search_cfg(&s);
    let out = run(&sp, &mut s, &cfg).unwrap();
    assert!(out.winner.score.accuracy >= out.acc_floor);
    assert!(
        out.winner.score.footprint <= out.baseline.score.footprint,
        "winner {} vs uniform baseline {}",
        out.winner.score.footprint,
        out.baseline.score.footprint
    );
    // the winner sits on the reported Pareto frontier
    assert!(out
        .pareto
        .iter()
        .any(|&(f, _)| f == out.winner.score.footprint));
}

#[test]
fn journal_record_validates_and_carries_the_search_rows() {
    let mut s = scorer(7, 0);
    let sp = space(&s);
    let out = run(&sp, &mut s, &search_cfg(&s)).unwrap();
    let rec = BenchRecord::from_autotune("native:native-mlp", &out);
    rec.validate().unwrap();
    assert_eq!(rec.bench, "autotune");
    for name in [
        "autotune/baseline_accuracy",
        "autotune/winner_accuracy",
        "autotune/winner_footprint",
        "autotune/search",
        "autotune/pareto/0",
    ] {
        assert!(rec.row(name).is_some(), "missing row {name}");
    }
    let search = rec.row("autotune/search").unwrap();
    assert_eq!(search.value, out.evaluated.max(1) as f64);
    assert_eq!(search.extra["groups"], out.groups as f64);
}

#[test]
fn emitted_toml_feeds_the_serve_recipe_loader_unmodified() {
    let mut s = scorer(7, 0);
    let sp = space(&s);
    let out = run(&sp, &mut s, &search_cfg(&s)).unwrap();
    // exactly what cmd_autotune writes: a comment header (the parser
    // strips comments) plus the [quant] section serve/tables load
    let text = format!(
        "# emitted by `ocs autotune` — fingerprint {}\n{}",
        out.winner.score.fingerprint,
        out.winner.recipe.to_toml("quant")
    );
    let parsed = QuantRecipe::from_toml(&Config::parse(&text).unwrap(), "quant").unwrap();
    assert_eq!(
        parsed.fingerprint(),
        out.winner.score.fingerprint,
        "the emitted TOML must resolve to the winning recipe, bit for bit"
    );
}
