//! Integration tests for the native integer backend: the packed i8 GEMM
//! kernel (property-tested against the naive i32 reference), the
//! prepared-model → i8 lowering, end-to-end native-vs-prepared logit
//! agreement, and the serve pool running real quantized compute with no
//! artifacts and no PJRT.

use std::sync::Arc;
use std::time::Duration;

use ocs::calib::slice_rows;
use ocs::clip::ClipMethod;
use ocs::kernels::gemm::{self, PackedB};
use ocs::miniprop::{check, ensure, gen_usize};
use ocs::model::store::WeightStore;
use ocs::model::{LayerKind, LayerSpec, ModelSpec};
use ocs::pipeline::{self, PreparedCache, QuantConfig, QuantRecipe, ServeConfig};
use ocs::quant::fake_quant_val;
use ocs::quant::pack::{pack_prepared, LayerBody};
use ocs::runtime::native::{native_calibrate, synthetic_mlp, NativeExecutable};
use ocs::serve::backend::{EngineFactory, NativeFactory, WorkerEngine};
use ocs::serve::Server;
use ocs::tensor::TensorF;
use ocs::util::rng::Rng;

fn rand_i8(rng: &mut Rng, n: usize) -> Vec<i8> {
    (0..n).map(|_| (rng.below(255) as i32 - 127) as i8).collect()
}

#[test]
fn property_packed_gemm_equals_naive_reference() {
    check("i8-gemm-vs-naive", |rng| {
        let m = gen_usize(rng, 1, 40);
        let k = gen_usize(rng, 1, 120);
        let n = gen_usize(rng, 1, 50);
        let a = rand_i8(rng, m * k);
        let b = rand_i8(rng, k * n);
        let want = gemm::gemm_i8_ref(&a, &b, m, k, n);
        let pb = PackedB::pack(&b, k, n);
        let got = gemm::gemm_i8(&a, &pb, m, 1);
        ensure(got == want, format!("packed != naive at {m}x{k}x{n}"))
    });
}

#[test]
fn property_parallel_gemm_bit_identical_at_any_width() {
    check("i8-gemm-thread-identity", |rng| {
        let m = gen_usize(rng, 1, 80);
        let k = gen_usize(rng, 1, 64);
        let n = gen_usize(rng, 1, 40);
        let a = rand_i8(rng, m * k);
        let b = rand_i8(rng, k * n);
        let pb = PackedB::pack(&b, k, n);
        let serial = gemm::gemm_i8(&a, &pb, m, 1);
        let threads = gen_usize(rng, 2, 16);
        let par = gemm::gemm_i8(&a, &pb, m, threads);
        ensure(par == serial, format!("threads {threads} diverged at {m}x{k}x{n}"))?;
        // the fused dequant epilogue too, bit for bit
        let scales: Vec<f32> = (0..n).map(|j| 0.002 + j as f32 * 1e-4).collect();
        let bias: Vec<f32> = (0..n).map(|j| j as f32 * 0.1).collect();
        let d1 = gemm::gemm_i8_dequant(&a, &pb, m, &scales, &bias, 1);
        let dn = gemm::gemm_i8_dequant(&a, &pb, m, &scales, &bias, threads);
        let b1: Vec<u32> = d1.iter().map(|v| v.to_bits()).collect();
        let bn: Vec<u32> = dn.iter().map(|v| v.to_bits()).collect();
        ensure(b1 == bn, format!("dequant threads {threads} diverged"))
    });
}

fn mlp_spec(cin: usize, hidden: usize, classes: usize) -> ModelSpec {
    let pad = |c: usize| (c as f64 * 1.25).ceil() as usize;
    let mk = |name: &str, cin: usize, cout: usize| LayerSpec {
        name: name.into(),
        kind: LayerKind::Fc,
        cin,
        cin_pad: pad(cin),
        cout,
        ksize: 0,
        stride: 1,
        quantized: true,
        w_cin_axis: 0,
        w_shape: vec![cin, cout],
        w_shape_pad: vec![pad(cin), cout],
    };
    ModelSpec {
        name: "it-native-mlp".into(),
        dir: std::path::PathBuf::new(),
        pad_factor: 1.25,
        num_classes: classes,
        img_hw: 0,
        img_c: 0,
        vocab: 0,
        seq_len: 0,
        momentum: 0.9,
        layers: vec![mk("f1", cin, hidden), mk("f2", hidden, classes)],
        artifacts: Default::default(),
    }
}

fn mlp_ws(spec: &ModelSpec, seed: u64) -> WeightStore {
    let mut rng = Rng::new(seed);
    let mut leaves = Vec::new();
    for l in &spec.layers {
        let mut w = rng.normal_vec(l.cin * l.cout);
        // plant an outlier channel for OCS to split
        for j in 0..l.cout {
            w[(l.cin / 2) * l.cout + j] *= 8.0;
        }
        leaves.push((
            format!("{}.W", l.name),
            TensorF::from_vec(&[l.cin, l.cout], w).unwrap(),
        ));
        leaves.push((
            format!("{}.b", l.name),
            TensorF::from_vec(&[l.cout], rng.normal_vec(l.cout)).unwrap(),
        ));
    }
    WeightStore::from_leaves(leaves)
}

/// f32 reference forward of a prepared 2-layer MLP, mirroring the
/// artifact semantics exactly: channel_dup → fake-quant → matmul+bias,
/// relu between layers. The native integer path must agree with this to
/// accumulation-rounding tolerance.
fn reference_forward(prep: &pipeline::PreparedModel, x: &[f32], batch: usize) -> Vec<f32> {
    let mut act: Vec<f32> = x.to_vec();
    let mut width = act.len() / batch;
    for (li, l) in prep.layers.iter().enumerate() {
        let ce = l.idx.len();
        let cout = l.b.len();
        // channel_dup
        let mut xe = vec![0.0f32; batch * ce];
        for r in 0..batch {
            for j in 0..ce {
                xe[r * ce + j] = act[r * width + l.idx.data()[j] as usize]
                    * l.dscale.data()[j]
                    + l.dbias.data()[j];
            }
        }
        // activation fake-quant (aqmax <= 0 bypasses)
        if l.aqmax > 0.0 {
            for v in xe.iter_mut() {
                *v = fake_quant_val(*v, l.adelta, l.aqmax);
            }
        }
        // matmul + bias against the fake-quantized weight
        let mut out = vec![0.0f32; batch * cout];
        for r in 0..batch {
            for j in 0..cout {
                let mut acc = l.b.data()[j];
                for kk in 0..ce {
                    acc += xe[r * ce + kk] * l.w.data()[kk * cout + j];
                }
                out[r * cout + j] = acc;
            }
        }
        if li + 1 < prep.layers.len() {
            for v in out.iter_mut() {
                *v = v.max(0.0);
            }
        }
        act = out;
        width = cout;
    }
    act
}

#[test]
fn native_logits_agree_with_prepared_pipeline() {
    let spec = mlp_spec(24, 12, 5);
    let ws = mlp_ws(&spec, 7);
    let mut rng = Rng::new(8);
    let batch = 6;
    let images = TensorF::from_vec(&[batch, 24], rng.normal_vec(batch * 24)).unwrap();
    let calib = native_calibrate(&spec, &ws, &images, batch).unwrap();
    for cfg in [
        QuantConfig::float(),
        QuantConfig::weights_only(4, ClipMethod::Mse, 0.1),
        QuantConfig {
            w_bits: Some(8),
            a_bits: Some(8),
            ocs_ratio: 0.1,
            ..QuantConfig::float()
        },
        QuantConfig {
            w_bits: Some(4),
            a_bits: Some(6),
            w_clip: ClipMethod::Mse,
            ..QuantConfig::float()
        },
    ] {
        let recipe = cfg.to_recipe();
        let prep = pipeline::prepare_recipe(&spec, &ws, Some(&calib), &recipe).unwrap();
        let exe = NativeExecutable::build(&spec, &prep).unwrap();
        let got = exe.infer(&images).unwrap();
        let want = reference_forward(&prep, images.data(), batch);
        assert_eq!(got.shape(), &[batch, 5]);
        let scale = want.iter().fold(1.0f32, |m, v| m.max(v.abs()));
        for (i, (&g, &w)) in got.data().iter().zip(&want).enumerate() {
            assert!(
                (g - w).abs() <= 1e-3 * scale,
                "[{}] logit {i}: native {g} vs prepared {w} (scale {scale})",
                recipe.label()
            );
        }
    }
}

#[test]
fn packed_layers_choose_int_exactly_when_datapath_allows() {
    let spec = mlp_spec(16, 8, 4);
    let ws = mlp_ws(&spec, 9);
    let mut rng = Rng::new(10);
    let images = TensorF::from_vec(&[8, 16], rng.normal_vec(8 * 16)).unwrap();
    let calib = native_calibrate(&spec, &ws, &images, 8).unwrap();
    // (recipe, expected int layers)
    let cases: Vec<(QuantRecipe, usize)> = vec![
        (QuantConfig::float().to_recipe(), 0),
        (QuantConfig::weights_only(4, ClipMethod::None, 0.0).to_recipe(), 0),
        (
            QuantConfig {
                w_bits: Some(4),
                a_bits: Some(8),
                ..QuantConfig::float()
            }
            .to_recipe(),
            2,
        ),
        (
            // mixed precision: one layer beyond i8, one inside
            QuantConfig {
                w_bits: Some(4),
                a_bits: Some(8),
                ..QuantConfig::float()
            }
            .to_recipe()
            .with_override(
                pipeline::LayerMatch::name("f2"),
                pipeline::LayerPolicy::w_bits(12),
            ),
            1,
        ),
    ];
    for (recipe, want_int) in cases {
        let calib_ref = if recipe.needs_calibration(&spec) {
            Some(&calib)
        } else {
            None
        };
        let prep = pipeline::prepare_recipe(&spec, &ws, calib_ref, &recipe).unwrap();
        let pm = pack_prepared(&spec, &prep).unwrap();
        assert_eq!(pm.int_layers, want_int, "[{}]", recipe.label());
        // every int body's dequant scale is adelta * wdelta
        for pl in pm.layers.values() {
            if let LayerBody::Int { dequant, wdelta, .. } = &pl.body {
                for &d in dequant {
                    assert_eq!(d.to_bits(), (pl.adelta * wdelta).to_bits());
                }
                // recovered grid is real (zero-width grids only pack
                // all-zero layers, which these weights are not)
                assert!(*wdelta > 0.0);
            }
        }
    }
}

#[test]
fn native_pool_serves_quantized_logits_artifact_free() {
    // weights + 8-bit activations: the full i8×i8 integer datapath
    // (weights-only would demote every layer to the f32 body)
    let recipe = QuantConfig::weights_with_a8(5, ClipMethod::Mse, 0.05).to_recipe();
    let factory = NativeFactory::synthetic(recipe.clone()).unwrap();
    let cache = factory.cache.clone();
    let (spec, ws, calib_slot) = (
        factory.spec.clone(),
        factory.ws.clone(),
        factory.calib.clone(),
    );
    let cfg = ServeConfig {
        workers: 2,
        max_batch: 8,
        max_wait: Duration::from_micros(200),
        queue_cap: 64,
        deadline: None,
        ..ServeConfig::default()
    };
    let server = Server::start_with(Arc::new(factory), cfg).unwrap();
    // both workers shared one prepare through the pool cache
    assert_eq!(cache.misses(), 1, "N workers, one prepare");
    assert_eq!(cache.hits(), 1);
    // and the pool really is serving the integer datapath: the shared
    // prep lowers both layers to packed i8 bodies
    {
        let calib = calib_slot.lock().unwrap();
        let prep = cache
            .get_or_prepare(&spec, &ws, calib.as_deref(), &recipe)
            .unwrap();
        let exe = NativeExecutable::build(&spec, &prep).unwrap();
        assert_eq!(exe.int_layers(), 2, "{}", exe.label());
    }
    let client = server.client();
    let images = ocs::train::data::synth_images(16, 33);
    let row0 = slice_rows(&images.x, 0, 1).unwrap();
    let logits = client.infer(row0.clone()).unwrap();
    assert_eq!(logits.len(), 10);
    assert!(logits.iter().all(|v| v.is_finite()));
    // deterministic across repeats (same worker or not)
    let again = client.infer(row0.clone()).unwrap();
    assert_eq!(logits, again);
    // hot-swap to float: the pool must converge and logits must move
    server.swap_recipe(QuantRecipe::float());
    let deadline = std::time::Instant::now() + Duration::from_secs(5);
    while server.swaps_applied() < 2 && std::time::Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(10));
    }
    assert_eq!(server.swaps_applied(), 2, "swap must roll out to both workers");
    let float_logits = client.infer(row0).unwrap();
    assert_ne!(logits, float_logits, "recipe swap must be observable");
    server.shutdown().unwrap();
    assert_eq!(cache.misses(), 2, "one more prepare for the float recipe");
}

#[test]
fn native_pool_batches_requests_correctly() {
    // several clients in flight: the worker fuses rows into one GEMM
    // batch; every client must get its own row back
    let recipe = QuantConfig::weights_only(4, ClipMethod::None, 0.0).to_recipe();
    let factory = NativeFactory::synthetic(recipe).unwrap();
    let cfg = ServeConfig {
        workers: 1,
        max_batch: 8,
        max_wait: Duration::from_millis(2),
        queue_cap: 64,
        deadline: None,
        ..ServeConfig::default()
    };
    let server = Server::start_with(Arc::new(factory), cfg).unwrap();
    let images = ocs::train::data::synth_images(12, 44);
    // ground truth: one at a time
    let mut solo = Vec::new();
    for i in 0..12 {
        let x = slice_rows(&images.x, i, 1).unwrap();
        solo.push(server.client().infer(x).unwrap());
    }
    // now concurrently, forcing fused batches
    let mut handles = Vec::new();
    for i in 0..12 {
        let client = server.client();
        let x = slice_rows(&images.x, i, 1).unwrap();
        handles.push(std::thread::spawn(move || client.infer(x).unwrap()));
    }
    for (i, h) in handles.into_iter().enumerate() {
        let got = h.join().unwrap();
        let want = &solo[i];
        for (a, b) in got.iter().zip(want) {
            assert_eq!(a.to_bits(), b.to_bits(), "request {i} got another row's logits");
        }
    }
    let batched = server.metrics().aggregate().batches;
    assert!(batched >= 1);
    server.shutdown().unwrap();
}

#[test]
fn synthetic_model_survives_prep_cache_lru() {
    // native worker swap across more recipes than the cache cap: late
    // swap-backs re-prepare (miss) instead of failing
    let (spec, ws) = synthetic_mlp(21);
    let recipe = QuantConfig::weights_only(4, ClipMethod::None, 0.0).to_recipe();
    let factory = NativeFactory::over(spec, ws, recipe).unwrap();
    factory.cache.set_capacity(2);
    let mut worker = factory.build(0).unwrap();
    let x = ocs::train::data::synth_images(1, 5).x;
    let base = worker.infer(&x).unwrap();
    for bits in [5u32, 6, 7] {
        worker
            .swap(&QuantConfig::weights_only(bits, ClipMethod::None, 0.0).to_recipe())
            .unwrap();
    }
    assert!(factory.cache.evictions() > 0, "cap 2 must evict across 4 recipes");
    // swapping back to the (evicted) original recipe still works
    worker
        .swap(&QuantConfig::weights_only(4, ClipMethod::None, 0.0).to_recipe())
        .unwrap();
    let again = worker.infer(&x).unwrap();
    let a: Vec<u32> = base.data().iter().map(|v| v.to_bits()).collect();
    let b: Vec<u32> = again.data().iter().map(|v| v.to_bits()).collect();
    assert_eq!(a, b, "re-prepared prep must serve identical logits");
}

#[test]
fn shared_cache_isolated_per_factory() {
    // two pools over different factories must not cross-share preps
    let r = QuantConfig::weights_only(4, ClipMethod::None, 0.0).to_recipe();
    let f1 = NativeFactory::synthetic(r.clone()).unwrap();
    let f2 = NativeFactory::synthetic(r).unwrap();
    assert!(!Arc::ptr_eq(&f1.cache, &f2.cache));
    let _w1 = f1.build(0).unwrap();
    let _w2 = f2.build(0).unwrap();
    assert_eq!((f1.cache.misses(), f2.cache.misses()), (1, 1));
    // an explicitly shared cache does share
    let (spec, ws) = synthetic_mlp(2027);
    let mut f3 = NativeFactory::over(
        spec,
        ws,
        QuantConfig::weights_only(4, ClipMethod::None, 0.0).to_recipe(),
    )
    .unwrap();
    f3.cache = f1.cache.clone();
    let _w3 = f3.build(0).unwrap();
    // same seed, same recipe: f3's build is a hit on f1's cache
    assert_eq!(
        (f1.cache.misses(), f1.cache.hits()),
        (1, 1),
        "shared cache must reuse the identical prep"
    );
}

#[test]
fn prepared_cache_reuse_is_bounded_wrt_global() {
    // the global cache respects a runtime capacity change
    let g = PreparedCache::global();
    let before = g.capacity();
    g.set_capacity(123);
    assert_eq!(g.capacity(), 123);
    g.set_capacity(before);
}
