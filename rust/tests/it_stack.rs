//! Integration tests over the real AOT artifacts: the full
//! L3 (Rust) -> L2 (JAX graph) -> L1 (Pallas kernels) stack through PJRT.
//!
//! These require `make artifacts`; each test skips (with a notice) when
//! the artifacts are absent so `cargo test` stays green pre-build.

use ocs::calib;
use ocs::clip::ClipMethod;
use ocs::eval;
use ocs::model::store::WeightStore;
use ocs::model::ModelSpec;
use ocs::pipeline::{self, QuantConfig};
use ocs::runtime::{Engine, Input, Inputs};
use ocs::train::{self, data};

fn have_artifacts() -> bool {
    let ok = std::path::Path::new("artifacts/manifest.json").exists();
    if !ok {
        eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
    }
    ok
}

/// Float probe logits must equal fwd-artifact logits under identity
/// hooks — the paper's §3.2 functional-equivalence invariant threaded
/// through the *real* compiled graph (padding, gather, bypassed quant).
#[test]
fn fwd_with_identity_hooks_matches_float_probe() {
    if !have_artifacts() {
        return;
    }
    let spec = ModelSpec::load_named("artifacts", "minivgg").unwrap();
    let ws = WeightStore::load_init(&spec).unwrap();
    let engine = Engine::cpu().unwrap();
    let imgs = data::synth_images(32, 77);

    // probe = float reference
    let probe = spec.probe_for_batch(32).unwrap();
    let pexe = engine.load(probe).unwrap();
    let mut pin: Inputs = Default::default();
    for io in &probe.inputs {
        if io.name == "x" {
            pin.insert("x".into(), Input::F32(imgs.x.clone()));
        } else {
            pin.insert(io.name.clone(), Input::F32(ws.bundle.f32(&io.name).unwrap().clone()));
        }
    }
    let pout = pexe.execute(&pin).unwrap();
    let ref_logits = pout.get("logits").unwrap();

    // fwd with float QuantConfig (identity hooks, quant bypassed)
    let prep = pipeline::prepare(&spec, &ws, None, &QuantConfig::float()).unwrap();
    let fwd = spec.fwd_for_batch(32).unwrap();
    let fexe = engine.load(fwd).unwrap();
    let mut fin: Inputs = Default::default();
    prep.insert_inputs(&mut fin);
    fin.insert("x".into(), Input::F32(imgs.x.clone()));
    let fout = fexe.execute(&fin).unwrap();
    let got = fout.get("logits").unwrap();

    assert_eq!(got.shape(), ref_logits.shape());
    for (a, b) in got.data().iter().zip(ref_logits.data()) {
        assert!((a - b).abs() < 1e-3, "{a} vs {b}");
    }
}

/// Weight OCS at high precision must preserve the function (Eq. 3):
/// 16-bit grids make quantization error negligible, so OCS'd logits
/// should track float logits closely and agree on argmax.
#[test]
fn weight_ocs_preserves_function_through_real_graph() {
    if !have_artifacts() {
        return;
    }
    let spec = ModelSpec::load_named("artifacts", "minivgg").unwrap();
    let ws = WeightStore::load_init(&spec).unwrap();
    let engine = Engine::cpu().unwrap();
    let imgs = data::synth_images(32, 78);

    let float_prep = pipeline::prepare(&spec, &ws, None, &QuantConfig::float()).unwrap();
    let ocs_prep = pipeline::prepare(
        &spec,
        &ws,
        None,
        &QuantConfig::weights_only(16, ClipMethod::None, 0.1),
    )
    .unwrap();
    assert!(ocs_prep.total_splits() > 0, "OCS must have split channels");

    let fwd = spec.fwd_for_batch(32).unwrap();
    let exe = engine.load(fwd).unwrap();
    let run = |prep: &pipeline::PreparedModel| {
        let mut inputs: Inputs = Default::default();
        prep.insert_inputs(&mut inputs);
        inputs.insert("x".into(), Input::F32(imgs.x.clone()));
        exe.execute(&inputs).unwrap().take("logits").unwrap()
    };
    let a = run(&float_prep);
    let b = run(&ocs_prep);
    let scale = a.max_abs().max(1.0);
    for (x, y) in a.data().iter().zip(b.data()) {
        assert!(
            (x - y).abs() / scale < 2e-3,
            "logit drift too large: {x} vs {y}"
        );
    }
    assert_eq!(a.argmax_rows(), b.argmax_rows());
}

/// Calibration produces per-layer stats for every quantized layer and
/// sane percentile ordering.
#[test]
fn calibration_covers_all_quantized_layers() {
    if !have_artifacts() {
        return;
    }
    let spec = ModelSpec::load_named("artifacts", "miniincept").unwrap();
    let ws = WeightStore::load_init(&spec).unwrap();
    let engine = Engine::cpu().unwrap();
    let imgs = data::synth_images(64, 79);
    let calib = calib::calibrate(&engine, &spec, &ws, &imgs.x, 32).unwrap();
    for l in spec.quantized_layers() {
        let lc = calib.layer(&l.name).unwrap();
        assert_eq!(lc.channel_max.len(), l.cin, "layer {}", l.name);
        assert_eq!(lc.outlier_counts.len(), l.cin);
        assert!(lc.hist.count() > 0);
        let p50 = lc.hist.percentile_abs(0.5);
        let p99 = lc.hist.percentile_abs(0.99);
        assert!(p99 >= p50);
    }
}

/// Activation quantization end-to-end: 8-bit acts should barely move
/// logits; 3-bit acts should move them a lot.
#[test]
fn activation_quant_bits_ordering() {
    if !have_artifacts() {
        return;
    }
    let spec = ModelSpec::load_named("artifacts", "minivgg").unwrap();
    let ws = WeightStore::load_init(&spec).unwrap();
    let engine = Engine::cpu().unwrap();
    let imgs = data::synth_images(64, 80);
    let calib = calib::calibrate(&engine, &spec, &ws, &imgs.x, 32).unwrap();
    let test = data::synth_images(32, 81);

    let fwd = spec.fwd_for_batch(32).unwrap();
    let exe = engine.load(fwd).unwrap();
    let run = |cfg: &QuantConfig| {
        let prep = pipeline::prepare(&spec, &ws, Some(&calib), cfg).unwrap();
        let mut inputs: Inputs = Default::default();
        prep.insert_inputs(&mut inputs);
        inputs.insert("x".into(), Input::F32(test.x.clone()));
        exe.execute(&inputs).unwrap().take("logits").unwrap()
    };
    let f = run(&QuantConfig::float());
    let a8 = run(&QuantConfig::acts_only(8, ClipMethod::None, 0.0));
    let a3 = run(&QuantConfig::acts_only(3, ClipMethod::None, 0.0));
    let drift = |x: &ocs::tensor::TensorF| -> f64 { f.mse(x) };
    assert!(drift(&a8) < drift(&a3), "8-bit must distort less than 3-bit");
    assert!(drift(&a8) > 0.0, "8-bit quantization is not a no-op");
}

/// A few SGD steps through the train artifact must reduce the loss.
#[test]
fn train_step_artifact_learns() {
    if !have_artifacts() {
        return;
    }
    let spec = ModelSpec::load_named("artifacts", "minivgg").unwrap();
    let ws = WeightStore::load_init(&spec).unwrap();
    let engine = Engine::cpu().unwrap();
    let dataset = data::synth_images(512, 82);
    let (_, report) = train::train_cnn(&engine, &spec, &ws, &dataset, 30, 0.05, 5).unwrap();
    let first = report.losses.first().unwrap().1;
    assert!(
        report.final_loss < first,
        "no learning: {first} -> {}",
        report.final_loss
    );
}

/// LSTM perplexity pipeline: float ppl must be far below the uniform
/// baseline (vocab) and 4-bit unclipped quantization must hurt.
#[test]
fn lstm_perplexity_pipeline() {
    if !have_artifacts() {
        return;
    }
    let spec = ModelSpec::load_named("artifacts", "lstmlm").unwrap();
    let (ws, _) = WeightStore::load_best(&spec).unwrap();
    let engine = Engine::cpu().unwrap();
    let corpus = data::synth_corpus(6_000, spec.vocab, 93);
    let windows = data::token_windows(&corpus, spec.seq_len, 32);
    let f = pipeline::prepare(&spec, &ws, None, &QuantConfig::float()).unwrap();
    let ppl_f = eval::perplexity(&engine, &spec, &f, &windows).unwrap();
    assert!(ppl_f < spec.vocab as f64, "ppl {ppl_f} vs uniform {}", spec.vocab);
    let q = pipeline::prepare(
        &spec,
        &ws,
        None,
        &QuantConfig::weights_only(4, ClipMethod::None, 0.0),
    )
    .unwrap();
    let ppl_q = eval::perplexity(&engine, &spec, &q, &windows).unwrap();
    assert!(ppl_q >= ppl_f * 0.99, "4-bit should not beat float: {ppl_q} vs {ppl_f}");
}

/// Serving: responses must match a direct artifact execution bit-for-bit
/// (same prepared inputs, same batch artifact when it lines up).
#[test]
fn serving_matches_direct_execution() {
    if !have_artifacts() {
        return;
    }
    use ocs::serve::{ServeConfig, Server};
    let server = Server::start(
        "artifacts",
        "minivgg",
        QuantConfig::float().to_recipe(),
        ServeConfig {
            workers: 1,
            max_batch: 1,
            max_wait: std::time::Duration::from_millis(1),
            queue_cap: 16,
            deadline: None,
            ..ServeConfig::default()
        },
    )
    .unwrap();
    let client = server.client();
    let imgs = data::synth_images(4, 84);
    let row = imgs.x.len() / imgs.len();

    // direct path
    let spec = ModelSpec::load_named("artifacts", "minivgg").unwrap();
    let (ws, _) = WeightStore::load_best(&spec).unwrap();
    let engine = Engine::cpu().unwrap();
    let prep = pipeline::prepare(&spec, &ws, None, &QuantConfig::float()).unwrap();
    let art = spec.fwd_for_batch(1).unwrap();
    let exe = engine.load(art).unwrap();

    for i in 0..4 {
        let x = ocs::tensor::TensorF::from_vec(
            &[1, 16, 16, 3],
            imgs.x.data()[i * row..(i + 1) * row].to_vec(),
        )
        .unwrap();
        let served = client.infer(x.clone()).unwrap();
        let mut inputs: Inputs = Default::default();
        prep.insert_inputs(&mut inputs);
        inputs.insert("x".into(), Input::F32(eval::pad_rows(&x, art.batch).unwrap()));
        let direct = exe.execute(&inputs).unwrap().take("logits").unwrap();
        for (a, b) in served.iter().zip(&direct.data()[..10]) {
            assert!((a - b).abs() < 1e-5, "served {a} vs direct {b}");
        }
    }
    server.shutdown().unwrap();
}

/// Accuracy evaluator handles non-multiple-of-batch test sets (padding
/// path) identically to an exact split.
#[test]
fn accuracy_padding_consistency() {
    if !have_artifacts() {
        return;
    }
    let spec = ModelSpec::load_named("artifacts", "minivgg").unwrap();
    let ws = WeightStore::load_init(&spec).unwrap();
    let engine = Engine::cpu().unwrap();
    let prep = pipeline::prepare(&spec, &ws, None, &QuantConfig::float()).unwrap();
    let d = data::synth_images(40, 85);
    // batch 32: one full chunk + one padded chunk of 8
    let acc_all = eval::accuracy(&engine, &spec, &prep, &d.x, &d.y, 32).unwrap();
    // same data evaluated at batch 8 (exact splits)
    let acc_b8 = eval::accuracy(&engine, &spec, &prep, &d.x, &d.y, 8).unwrap();
    assert!((acc_all - acc_b8).abs() < 1e-9, "{acc_all} vs {acc_b8}");
}
