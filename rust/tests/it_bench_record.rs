//! End-to-end tests for the versioned benchmark records: the committed
//! baselines under `records/` must parse and pass `ocs bench check`
//! with exactly the gates CI applies, and `ocs bench diff` over the
//! golden fixture pairs must render per-case ratios and exit nonzero on
//! the injected regression (the gate CI relies on, exercised through
//! the real binary).

use std::path::PathBuf;
use std::process::{Command, Output};

use ocs::bench_record::diff::{diff, Verdict};
use ocs::bench_record::BenchRecord;

fn records_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../records")
}

fn run_ocs(args: &[&str]) -> Output {
    Command::new(env!("CARGO_BIN_EXE_ocs"))
        .args(args)
        .current_dir(records_dir())
        .output()
        .expect("spawn ocs")
}

fn stdout(out: &Output) -> String {
    String::from_utf8_lossy(&out.stdout).into_owned()
}

fn stderr(out: &Output) -> String {
    String::from_utf8_lossy(&out.stderr).into_owned()
}

// ---- committed baselines -------------------------------------------------

#[test]
fn committed_baselines_parse_and_validate() {
    for name in [
        "BENCH_quant.json",
        "BENCH_native.json",
        "BENCH_serving.json",
        "BENCH_loadtest.json",
    ] {
        let rec = BenchRecord::load(&records_dir().join(name)).unwrap();
        rec.validate().unwrap_or_else(|e| panic!("{name}: {e}"));
    }
}

#[test]
fn baselines_pass_the_ci_check_gates() {
    // the exact flags .github/workflows/ci.yml runs after each harness
    let quant = run_ocs(&[
        "bench",
        "check",
        "BENCH_quant.json",
        "--bench",
        "quant",
        "--require",
        "perchan_quant,calib_stats,kl_sweep,ocs_transform",
        "--speedup-prefix",
        "perchan_quant/fused",
        "--min-speedup",
        "1.0",
    ]);
    assert!(quant.status.success(), "{}", stderr(&quant));
    assert!(stdout(&quant).contains("ok"), "{}", stdout(&quant));

    let native = run_ocs(&[
        "bench",
        "check",
        "BENCH_native.json",
        "--bench",
        "native",
        "--require",
        "i8_gemm/naive_serial,i8_gemm/packed_t,native_infer",
        "--speedup-prefix",
        "i8_gemm/packed_t",
        "--min-speedup",
        "1.0",
    ]);
    assert!(native.status.success(), "{}", stderr(&native));

    let serving = run_ocs(&["bench", "check", "BENCH_serving.json", "--bench", "serving"]);
    assert!(serving.status.success(), "{}", stderr(&serving));

    // the gate loadtest-smoke applies to its freshly generated record
    let loadtest = run_ocs(&[
        "bench",
        "check",
        "BENCH_loadtest.json",
        "--bench",
        "loadtest",
        "--require",
        "loadtest/c1,loadtest/saturation",
    ]);
    assert!(loadtest.status.success(), "{}", stderr(&loadtest));
}

#[test]
fn check_rejects_wrong_tag_missing_prefix_and_weak_speedup() {
    let wrong_tag = run_ocs(&["bench", "check", "BENCH_quant.json", "--bench", "native"]);
    assert!(!wrong_tag.status.success());
    assert!(stderr(&wrong_tag).contains("bench tag"), "{}", stderr(&wrong_tag));

    let missing = run_ocs(&["bench", "check", "BENCH_quant.json", "--require", "no_such_case"]);
    assert!(!missing.status.success());
    assert!(stderr(&missing).contains("no_such_case"), "{}", stderr(&missing));

    let weak = run_ocs(&[
        "bench",
        "check",
        "BENCH_quant.json",
        "--speedup-prefix",
        "perchan_quant/fused",
        "--min-speedup",
        "1000",
    ]);
    assert!(!weak.status.success());
    assert!(stderr(&weak).contains("speedup"), "{}", stderr(&weak));
}

#[test]
fn check_rejects_stale_schema_and_bad_values() {
    let stale = run_ocs(&["bench", "check", "fixtures/quant_stale_schema.json"]);
    assert!(!stale.status.success());
    assert!(stderr(&stale).contains("schema v0"), "{}", stderr(&stale));

    let bad = run_ocs(&["bench", "check", "fixtures/quant_bad_value.json"]);
    assert!(!bad.status.success());
    assert!(stderr(&bad).contains("non-positive"), "{}", stderr(&bad));

    let gone = run_ocs(&["bench", "check", "fixtures/does_not_exist.json"]);
    assert!(!gone.status.success());
}

// ---- golden diff pairs through the real binary ---------------------------

#[test]
fn diff_exits_nonzero_on_injected_regression() {
    let out = run_ocs(&[
        "bench",
        "diff",
        "fixtures/quant_base.json",
        "fixtures/quant_regressed.json",
    ]);
    assert!(!out.status.success(), "regression must gate");
    let table = stdout(&out);
    assert!(table.contains("REGRESSED"), "{table}");
    assert!(table.contains("1.75x"), "{table}");
    assert!(stderr(&out).contains("regressed past"), "{}", stderr(&out));
}

#[test]
fn diff_allow_regression_reports_but_passes() {
    let out = run_ocs(&[
        "bench",
        "diff",
        "fixtures/quant_base.json",
        "fixtures/quant_regressed.json",
        "--allow-regression",
    ]);
    assert!(out.status.success(), "{}", stderr(&out));
    assert!(stdout(&out).contains("REGRESSED"));
}

#[test]
fn diff_passes_on_improvement_and_noise() {
    let improved = run_ocs(&[
        "bench",
        "diff",
        "fixtures/quant_base.json",
        "fixtures/quant_improved.json",
    ]);
    assert!(improved.status.success(), "{}", stderr(&improved));
    assert!(stdout(&improved).contains("improved"));

    let noise = run_ocs(&[
        "bench",
        "diff",
        "fixtures/quant_base.json",
        "fixtures/quant_noise.json",
    ]);
    assert!(noise.status.success(), "{}", stderr(&noise));
    assert!(stdout(&noise).contains("within noise"));
    assert!(!stdout(&noise).contains("REGRESSED"));
}

#[test]
fn diff_mad_band_gates_tight_cases_but_forgives_wobbly_ones() {
    // both cases drift 1.35x past the 25% global threshold, but the
    // baseline recorded wobbly's spread (mad 20µs on 100µs → ±60% band):
    // only the steady case may gate
    let out = run_ocs(&[
        "bench",
        "diff",
        "fixtures/quant_mad_base.json",
        "fixtures/quant_mad_noise.json",
    ]);
    assert!(!out.status.success(), "the tight case must still gate");
    let table = stdout(&out);
    assert!(table.contains("mad band ±60%"), "{table}");
    assert!(
        table.contains("1 case(s) regressed past the 25% threshold"),
        "{table}"
    );
}

#[test]
fn mad_fixture_verdicts_match_the_library_diff() {
    let base = BenchRecord::load(&records_dir().join("fixtures/quant_mad_base.json")).unwrap();
    let noise = BenchRecord::load(&records_dir().join("fixtures/quant_mad_noise.json")).unwrap();
    let d = diff(&base, &noise, 0.25).unwrap();
    assert_eq!(d.regressions().count(), 1);
    assert_eq!(d.regressions().next().unwrap().name, "perchan_quant/steady/256x256");
    let wobbly = d
        .rows
        .iter()
        .find(|r| r.name == "perchan_quant/wobbly/256x256")
        .unwrap();
    assert_eq!(wobbly.verdict, Verdict::WithinNoise);
    assert!((wobbly.threshold - 0.6).abs() < 1e-12, "mad widens the band");
}

#[test]
fn diff_reports_added_and_removed_without_failing() {
    let out = run_ocs(&[
        "bench",
        "diff",
        "fixtures/quant_base.json",
        "fixtures/quant_churn.json",
    ]);
    assert!(out.status.success(), "{}", stderr(&out));
    let table = stdout(&out);
    assert!(table.contains("+ new_path/fused/64x64"), "{table}");
    assert!(table.contains("- ocs_transform/fused/256x256+32"), "{table}");
}

#[test]
fn diff_threshold_flag_moves_the_gate() {
    // the 1.75x injected regression passes under a generous cross-host
    // tripwire (what CI's bench-gate job uses)
    let out = run_ocs(&[
        "bench",
        "diff",
        "fixtures/quant_base.json",
        "fixtures/quant_regressed.json",
        "--threshold",
        "9.0",
    ]);
    assert!(out.status.success(), "{}", stderr(&out));
    // and the within-noise pair fails under a hair-trigger threshold
    let strict = run_ocs(&[
        "bench",
        "diff",
        "fixtures/quant_base.json",
        "fixtures/quant_noise.json",
        "--threshold",
        "0.01",
    ]);
    assert!(!strict.status.success());
}

#[test]
fn diff_summary_appends_markdown() {
    let dir = std::env::temp_dir().join(format!("ocs_bench_summary_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let summary = dir.join("summary.md");
    let out = run_ocs(&[
        "bench",
        "diff",
        "fixtures/quant_base.json",
        "fixtures/quant_regressed.json",
        "--allow-regression",
        "--summary",
        summary.to_str().unwrap(),
    ]);
    assert!(out.status.success(), "{}", stderr(&out));
    let md = std::fs::read_to_string(&summary).unwrap();
    assert!(md.contains("### bench diff: `quant`"), "{md}");
    assert!(md.contains("| `perchan_quant/fused_t4/256x256` |"), "{md}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn history_renders_the_committed_records() {
    // what bench-gate appends to the job summary: a trajectory table
    // over records/ (fixtures/ is a subdirectory, so never included)
    let dir = std::env::temp_dir().join(format!("ocs_bench_history_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let summary = dir.join("summary.md");
    let out = run_ocs(&["bench", "history", ".", "--summary", summary.to_str().unwrap()]);
    assert!(out.status.success(), "{}", stderr(&out));
    let t = stdout(&out);
    assert!(t.contains("bench history [quant]"), "{t}");
    assert!(t.contains("bench history [loadtest]"), "{t}");
    assert!(!t.contains("quant_mad_base"), "fixtures must not leak in: {t}");
    let md = std::fs::read_to_string(&summary).unwrap();
    assert!(md.contains("### bench history: `loadtest`"), "{md}");
    assert!(md.contains("| `loadtest/saturation` |"), "{md}");
    std::fs::remove_dir_all(&dir).ok();
}

// ---- library-level agreement with the fixtures ---------------------------

#[test]
fn fixture_verdicts_match_the_library_diff() {
    let base = BenchRecord::load(&records_dir().join("fixtures/quant_base.json")).unwrap();
    let reg = BenchRecord::load(&records_dir().join("fixtures/quant_regressed.json")).unwrap();
    let d = diff(&base, &reg, 0.25).unwrap();
    assert!(d.has_regressions());
    assert_eq!(d.regressions().count(), 1);
    let r = d.regressions().next().unwrap();
    assert_eq!(r.name, "perchan_quant/fused_t4/256x256");
    assert!((r.factor - 1.75).abs() < 1e-9);
    let within = d.rows.iter().filter(|r| r.verdict == Verdict::WithinNoise);
    assert_eq!(within.count(), 2);
}
