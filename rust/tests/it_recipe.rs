//! Integration tests for the recipe API at pool scale, artifact-free:
//! the real quantization pipeline (recipe resolution, OCS, clip,
//! fake-quant) over in-memory models, served through the sharded pool
//! on the quant-sim backend. Covers the PR's acceptance criteria:
//! prepared-model cache sharing across serve workers and table-style
//! sweeps, mixed-precision recipes end-to-end, and serve-time recipe
//! hot-swap.

use std::sync::Arc;
use std::time::{Duration, Instant};

use ocs::calib::{Calibration, LayerCalib};
use ocs::clip::ClipMethod;
use ocs::model::store::WeightStore;
use ocs::model::{LayerKind, LayerSpec, ModelSpec};
use ocs::pipeline::{self, PreparedCache, QuantConfig, QuantRecipe, ServeConfig};
use ocs::serve::backend::{EngineFactory, QuantSimFactory};
use ocs::serve::Server;
use ocs::stats::Histogram;
use ocs::tensor::TensorF;
use ocs::util::rng::Rng;

fn fc_layer(name: &str) -> LayerSpec {
    LayerSpec {
        name: name.into(),
        kind: LayerKind::Fc,
        cin: 8,
        cin_pad: 10,
        cout: 4,
        ksize: 0,
        stride: 1,
        quantized: true,
        w_cin_axis: 0,
        w_shape: vec![8, 4],
        w_shape_pad: vec![10, 4],
    }
}

fn trio_spec() -> ModelSpec {
    ModelSpec {
        name: "it_trio".into(),
        dir: std::path::PathBuf::new(),
        pad_factor: 1.25,
        num_classes: 4,
        img_hw: 0,
        img_c: 0,
        vocab: 0,
        seq_len: 0,
        momentum: 0.9,
        layers: vec![fc_layer("f1"), fc_layer("f2"), fc_layer("f3")],
        artifacts: Default::default(),
    }
}

fn trio_ws(seed: u64) -> WeightStore {
    let mut rng = Rng::new(seed);
    let mut leaves = Vec::new();
    for name in ["f1", "f2", "f3"] {
        let mut w = rng.normal_vec(32);
        w[5 * 4] = 11.0; // outlier channel 5
        leaves.push((format!("{name}.W"), TensorF::from_vec(&[8, 4], w).unwrap()));
        leaves.push((format!("{name}.b"), TensorF::zeros(&[4])));
    }
    WeightStore::from_leaves(leaves)
}

fn trio_calib() -> Calibration {
    let data: Vec<f32> = (0..4096).map(|i| (i % 64) as f32 * 0.05).collect();
    let mut layers = std::collections::BTreeMap::new();
    for name in ["f1", "f2", "f3"] {
        let mut channel_max = vec![1.0f32; 8];
        channel_max[3] = 6.0;
        let mut outlier_counts = vec![0u64; 8];
        outlier_counts[3] = 40;
        layers.insert(
            name.to_string(),
            LayerCalib {
                hist: Histogram::from_slice(&data, 256),
                channel_max,
                outlier_counts,
            },
        );
    }
    Calibration { layers }
}

fn factory(recipe: QuantRecipe, cache: Arc<PreparedCache>) -> Arc<QuantSimFactory> {
    Arc::new(QuantSimFactory {
        spec: Arc::new(trio_spec()),
        ws: Arc::new(trio_ws(42)),
        calib: Some(Arc::new(trio_calib())),
        recipe,
        cache,
    })
}

fn serve_cfg(workers: usize) -> ServeConfig {
    ServeConfig {
        workers,
        max_batch: 8,
        max_wait: Duration::from_micros(200),
        queue_cap: 64,
        deadline: None,
        ..ServeConfig::default()
    }
}

fn img(seed: f32) -> TensorF {
    let data: Vec<f32> = (0..12).map(|i| seed + i as f32 * 0.125).collect();
    TensorF::from_vec(&[1, 12], data).unwrap()
}

/// Acceptance: a 4-worker serve start prepares once and shares —
/// misses = 1, hits = workers - 1 on a private cache.
#[test]
fn four_worker_start_shares_one_prep() {
    let cache = Arc::new(PreparedCache::new());
    let recipe = QuantConfig::weights_only(5, ClipMethod::Mse, 0.05).to_recipe();
    let server = Server::start_with(factory(recipe, cache.clone()), serve_cfg(4)).unwrap();
    assert_eq!(server.worker_count(), 4);
    assert_eq!(cache.misses(), 1, "exactly one prepare across the pool");
    assert_eq!(cache.hits(), 3, "the other three workers shared it");
    // and the pool actually serves on that shared prep
    let client = server.client();
    let logits = client.infer(img(0.5)).unwrap();
    assert_eq!(logits.len(), 4);
    server.shutdown().unwrap();
}

/// Acceptance: a tables-style sweep (clip search, then re-running the
/// winning cell, as table 2's "OCS + best clip" column does) hits the
/// cache on every revisited point.
#[test]
fn table_sweep_revisits_hit_the_cache() {
    let cache = PreparedCache::new();
    let spec = trio_spec();
    let ws = trio_ws(7);
    let clips = [ClipMethod::None, ClipMethod::Mse, ClipMethod::Aciq, ClipMethod::Kl];
    // sweep: accuracy of every clip method at 4 bits
    let mut best = ClipMethod::None;
    let mut best_sig = f64::MIN;
    for m in clips {
        let recipe = QuantConfig::weights_only(4, m, 0.0).to_recipe();
        let prep = cache.get_or_prepare(&spec, &ws, None, &recipe).unwrap();
        // stand-in for "accuracy": any deterministic score off the prep
        let sig: f64 = prep.layers.iter().map(|l| l.w_threshold as f64).sum();
        if sig > best_sig {
            best_sig = sig;
            best = m;
        }
    }
    assert_eq!(cache.misses(), 4);
    assert_eq!(cache.hits(), 0);
    // "best clip" re-run: the winning cell must not prepare again
    let again = QuantConfig::weights_only(4, best, 0.0).to_recipe();
    let _ = cache.get_or_prepare(&spec, &ws, None, &again).unwrap();
    assert_eq!(cache.misses(), 4, "revisit did not re-prepare");
    assert!(cache.hits() >= 1, "revisit hit the cache");
}

/// Acceptance: a mixed-precision recipe (8-bit first/last, 4-bit
/// middle) prepares and serves end-to-end on the sim backend, and its
/// logits differ from the uniform 4-bit recipe's (the per-layer grids
/// really differ).
#[test]
fn mixed_precision_recipe_serves_on_sim() {
    let mixed = QuantConfig::weights_only(4, ClipMethod::None, 0.0)
        .to_recipe()
        .edge_w_bits(8);
    // sanity: the recipe resolves as designed before it ever serves
    let spec = trio_spec();
    let prep = pipeline::prepare_recipe(&spec, &trio_ws(42), None, &mixed).unwrap();
    let q = |l: &ocs::pipeline::LayerPrep, qmax: f32| {
        let delta = l.w_threshold / qmax;
        l.w.data().iter().all(|&v| {
            let k = v / delta;
            (k - k.round()).abs() < 1e-3
        })
    };
    assert!(q(&prep.layers[0], 127.0), "first layer on the 8-bit grid");
    assert!(q(&prep.layers[1], 7.0), "middle layer on the 4-bit grid");
    assert!(q(&prep.layers[2], 127.0), "last layer on the 8-bit grid");

    let cache = Arc::new(PreparedCache::new());
    let server =
        Server::start_with(factory(mixed.clone(), cache.clone()), serve_cfg(2)).unwrap();
    let client = server.client();
    let mixed_logits = client.infer(img(1.0)).unwrap();
    assert_eq!(mixed_logits.len(), 4);
    server.shutdown().unwrap();

    let uniform = QuantConfig::weights_only(4, ClipMethod::None, 0.0).to_recipe();
    let server2 = Server::start_with(factory(uniform, cache.clone()), serve_cfg(1)).unwrap();
    let uniform_logits = server2.client().infer(img(1.0)).unwrap();
    assert_ne!(
        mixed_logits, uniform_logits,
        "mixed precision must serve a different prep than uniform 4-bit"
    );
    server2.shutdown().unwrap();
    assert_eq!(cache.misses(), 2, "two recipes, two preps, pool-wide");
}

/// Acceptance: serve-time recipe hot-swap — the pool rolls to a new
/// recipe without restarting, old responses drain, new responses serve
/// the new prep, and the swap prepares once per recipe pool-wide.
#[test]
fn recipe_hot_swap_rolls_the_pool() {
    let cache = Arc::new(PreparedCache::new());
    let r_before = QuantConfig::weights_only(4, ClipMethod::None, 0.0).to_recipe();
    let r_after = QuantConfig::weights_only(8, ClipMethod::Mse, 0.1)
        .to_recipe()
        .edge_w_bits(5);
    let f = factory(r_before.clone(), cache.clone());
    let server = Server::start_with(f, serve_cfg(3)).unwrap();
    let client = server.client();

    let before = client.infer(img(2.0)).unwrap();
    assert_eq!(cache.misses(), 1);

    server.swap_recipe(r_after.clone());
    let t0 = Instant::now();
    while server.swaps_applied() < 3 {
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "swap did not roll out: {}/3 applied",
            server.swaps_applied()
        );
        std::thread::sleep(Duration::from_millis(5));
    }
    assert_eq!(cache.misses(), 2, "three workers swapped, one prepare");

    let after = client.infer(img(2.0)).unwrap();
    assert_ne!(before, after, "the swap must change what the pool serves");
    // the new logits match a fresh worker built directly on the new recipe
    let mut direct = factory(r_after, cache.clone()).build(9).unwrap();
    let expect = direct.infer(&img(2.0)).unwrap();
    assert_eq!(after, expect.data()[..4].to_vec());

    // swap *back*: no new prepare (the old prep is still cached)
    server.swap_recipe(r_before);
    let t1 = Instant::now();
    while server.swaps_applied() < 6 {
        assert!(t1.elapsed() < Duration::from_secs(10), "swap-back stalled");
        std::thread::sleep(Duration::from_millis(5));
    }
    assert_eq!(cache.misses(), 2, "swap-back reused the cached prep");
    let back = client.infer(img(2.0)).unwrap();
    assert_eq!(before, back, "swap-back restores the original behaviour");
    assert_eq!(server.metrics().aggregate().swap_errors, 0);
    server.shutdown().unwrap();
}

/// Hot-swap on a backend that holds no prep (the plain burn sim) must
/// fail soft: swap errors are counted, serving continues on the old
/// behaviour, and no worker dies.
#[test]
fn hot_swap_failure_keeps_serving() {
    use ocs::serve::backend::SimFactory;
    let server = Server::start_with(
        Arc::new(SimFactory {
            classes: 3,
            cost_per_batch: Duration::ZERO,
            cost_per_item: Duration::ZERO,
        }),
        serve_cfg(2),
    )
    .unwrap();
    let client = server.client();
    let before = client.infer(img(1.0)).unwrap();
    server.swap_recipe(QuantRecipe::float());
    let t0 = Instant::now();
    while server.metrics().aggregate().swap_errors < 2 {
        assert!(t0.elapsed() < Duration::from_secs(10), "swap errors not recorded");
        std::thread::sleep(Duration::from_millis(5));
    }
    assert_eq!(server.swaps_applied(), 0, "nothing actually swapped");
    let after = client.infer(img(1.0)).unwrap();
    assert_eq!(before, after, "old behaviour keeps serving");
    server.shutdown().unwrap();
}
