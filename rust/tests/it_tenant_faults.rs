//! Integration tests for tenant-level fault isolation: the per-tenant
//! circuit breaker (crash-looping tenant quarantined while siblings
//! stay bit-stable and no *worker* breaker opens), `--tenant-fallback`
//! rerouting to the default prep, transactional recipe-sync rollback
//! (`panic-on-sync` leaves the worker alive on its previous prep), the
//! half-open probe re-admission path, the per-tenant quota gauge
//! lifecycle across panic-failed jobs, and the chaos drill matrix gate
//! — all driven through deterministic [`FaultPlan`] schedules on the
//! sim and native backends, no artifacts needed.

use std::sync::{Arc, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use ocs::bench_record::BenchRecord;
use ocs::clip::ClipMethod;
use ocs::pipeline::{QuantConfig, QuantRecipe, ServeConfig};
use ocs::serve::backend::{NativeFactory, SimFactory};
use ocs::serve::faults::FaultPlan;
use ocs::serve::{chaos_matrix, Server, TenantInit, TenantTable};
use ocs::tensor::TensorF;

/// Same discipline as `it_faults`: these tests run pools and burn CPU;
/// serialize them so they don't corrupt each other's timing.
static SERIAL: Mutex<()> = Mutex::new(());

fn serial() -> MutexGuard<'static, ()> {
    SERIAL.lock().unwrap_or_else(|e| e.into_inner())
}

/// Pool config with a fast supervisor (1 ms backoff base) and a long
/// quarantine so breaker assertions aren't raced by a half-open probe.
fn cfg(workers: usize) -> ServeConfig {
    ServeConfig {
        workers,
        max_batch: 4,
        max_wait: Duration::from_micros(200),
        queue_cap: 64,
        deadline: None,
        backoff: Duration::from_millis(1),
        quarantine: Duration::from_secs(60),
        ..ServeConfig::default()
    }
}

fn sim() -> Arc<SimFactory> {
    Arc::new(SimFactory::default())
}

fn recipe(w_bits: u32) -> QuantRecipe {
    let mut c = QuantConfig::weights_only(w_bits, ClipMethod::Mse, 0.02);
    c.a_bits = Some(8);
    c.to_recipe()
}

fn tenant(name: &str, weight: f64, r: Option<QuantRecipe>) -> TenantInit {
    TenantInit {
        name: name.into(),
        weight,
        recipe: r,
    }
}

fn table(tenants: &[TenantInit]) -> TenantTable {
    TenantTable::new(tenants).unwrap()
}

/// One fixed `(1, 16, 16, 3)` image for the synthetic MLP.
fn image() -> TensorF {
    let ds = ocs::train::data::synth_images(4, 77);
    ocs::calib::slice_rows(&ds.x, 0, 1).unwrap()
}

/// Retry a tenant infer until the pool serves it (respawn windows
/// reject or fail requests); panics after `secs` seconds of failures.
fn infer_tenant_until_ok(
    client: &ocs::serve::Client,
    name: &str,
    x: &TensorF,
    secs: u64,
) -> Vec<f32> {
    let t0 = Instant::now();
    loop {
        match client.infer_tenant(name, x.clone()) {
            Ok(logits) => return logits,
            Err(e) => {
                if t0.elapsed() > Duration::from_secs(secs) {
                    panic!("tenant '{name}' never served: last error: {e:#}");
                }
                std::thread::sleep(Duration::from_millis(2));
            }
        }
    }
}

#[test]
fn crash_looping_tenant_is_quarantined_and_siblings_stay_bit_stable() {
    let _guard = serial();
    let tenants = [
        tenant("gold", 1.0, Some(QuantConfig::float().to_recipe())),
        tenant("bulk", 1.0, Some(recipe(3))),
    ];
    let x = image();
    // fault-free run: the reference logits for the sibling check
    let clean = Arc::new(NativeFactory::synthetic(recipe(5)).unwrap());
    let server = Server::start_tenants(clean, cfg(2), table(&tenants)).unwrap();
    let client = server.client();
    let default_ref = infer_tenant_until_ok(&client, "default", &x, 5);
    let bulk_ref = infer_tenant_until_ok(&client, "bulk", &x, 5);
    server.shutdown().unwrap();
    // same pool, but gold's every batch panics (the crash loop). The
    // tenant breaker must quarantine gold after `tenant_restart_max`
    // strikes — long before any worker burns its restart budget.
    let mut c = cfg(2);
    c.restart_max = 10; // ample worker budget: the tenant breaker must fire first
    c.tenant_restart_max = 3;
    let plan = FaultPlan::parse("panic-tenant:gold").unwrap();
    let faulty = plan.wrap(Arc::new(NativeFactory::synthetic(recipe(5)).unwrap()));
    let server = Server::start_tenants(faulty, c, table(&tenants)).unwrap();
    let client = server.client();
    let t0 = Instant::now();
    let quarantine_err = loop {
        match client.infer_tenant("gold", x.clone()) {
            Ok(_) => panic!("gold must not serve while crash-looping"),
            Err(e) => {
                let msg = format!("{e:#}");
                if msg.contains("quarantined") {
                    break msg;
                }
            }
        }
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "tenant breaker never tripped"
        );
        std::thread::sleep(Duration::from_millis(2));
    };
    assert!(quarantine_err.contains("gold"), "{quarantine_err}");
    assert!(server.tenant_quarantined("gold"));
    let gold_id = client.tenant_id("gold").unwrap();
    assert!(server.metrics().tenant_quarantined_count(gold_id) >= 1);
    // siblings ride through bit-identical to the fault-free pool
    assert_eq!(infer_tenant_until_ok(&client, "default", &x, 5), default_ref);
    assert_eq!(infer_tenant_until_ok(&client, "bulk", &x, 5), bulk_ref);
    let agg = server.metrics().aggregate();
    assert!(agg.panics >= 3, "one panic per strike: {agg:?}");
    assert_eq!(
        server.dead_workers(),
        0,
        "tenant quarantine must spare the worker breakers"
    );
    server.shutdown().unwrap();
}

#[test]
fn tenant_fallback_serves_default_prep_answers() {
    let _guard = serial();
    // gold is lowered aggressively so its own prep's logits are
    // distinguishable from the default prep's
    let tenants = [tenant("gold", 1.0, Some(recipe(3)))];
    let x = image();
    let mut c = cfg(1);
    c.tenant_restart_max = 1;
    c.tenant_fallback = true;
    let factory = Arc::new(NativeFactory::synthetic(recipe(5)).unwrap());
    let server = Server::start_tenants(factory, c, table(&tenants)).unwrap();
    let client = server.client();
    let default_ref = client.infer(x.clone()).unwrap();
    let gold_own = client.infer_tenant("gold", x.clone()).unwrap();
    assert_ne!(gold_own, default_ref, "preps must differ for this drill");
    // trip the breaker directly (tenant_restart_max = 1: one strike)
    let gold_id = client.tenant_id("gold").unwrap();
    assert!(server.tenant_breaker().record_strike(gold_id));
    assert!(server.tenant_quarantined("gold"));
    // quarantined + fallback: gold's requests are served, on the
    // default prep, instead of being rejected
    let rerouted = client.infer_tenant("gold", x.clone()).unwrap();
    assert_eq!(rerouted, default_ref, "fallback must use the default prep");
    assert!(server.metrics().tenant_quarantined_count(gold_id) >= 1);
    assert_eq!(
        server.metrics().tenant_rejected_count(gold_id),
        0,
        "fallback reroutes instead of rejecting"
    );
    server.shutdown().unwrap();
}

#[test]
fn panic_on_sync_rolls_back_and_the_worker_survives() {
    let _guard = serial();
    let tenants = [tenant("gold", 1.0, Some(recipe(3)))];
    let x = image();
    let plan = FaultPlan::parse("panic-on-sync:gold@1").unwrap();
    let factory = plan.wrap(Arc::new(NativeFactory::synthetic(recipe(5)).unwrap()));
    let server = Server::start_tenants(factory, cfg(1), table(&tenants)).unwrap();
    let client = server.client();
    let pre = client.infer_tenant("gold", x.clone()).unwrap();
    // publish a hot swap; the sync panics mid-apply on worker 0, which
    // must roll back to the previous lowered executable and stay alive
    server
        .swap_tenant_recipe("gold", QuantRecipe::float())
        .unwrap();
    let t0 = Instant::now();
    while server.metrics().aggregate().swap_aborts == 0 {
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "sync abort never recorded"
        );
        std::thread::sleep(Duration::from_millis(2));
    }
    // the worker is alive and serving on the *previous* prep
    let post = infer_tenant_until_ok(&client, "gold", &x, 5);
    assert_eq!(post, pre, "rollback must restore the pre-swap prep");
    let agg = server.metrics().aggregate();
    assert!(agg.swap_aborts >= 1, "{agg:?}");
    assert!(agg.panics >= 1, "the contained sync panic counts: {agg:?}");
    assert_eq!(agg.restarts, 0, "no worker death, no respawn: {agg:?}");
    assert_eq!(server.dead_workers(), 0);
    server.shutdown().unwrap();
}

#[test]
fn half_open_probe_readmits_a_recovered_tenant() {
    let _guard = serial();
    let tenants = [tenant("gold", 1.0, None)];
    let mut c = cfg(1);
    c.tenant_restart_max = 1;
    c.quarantine = Duration::from_millis(50);
    let server = Server::start_tenants(sim(), c, table(&tenants)).unwrap();
    let client = server.client();
    let x = image();
    let gold_id = client.tenant_id("gold").unwrap();
    assert!(server.tenant_breaker().record_strike(gold_id));
    let err = client
        .infer_tenant("gold", x.clone())
        .expect_err("quarantined tenant must be rejected")
        .to_string();
    assert!(err.contains("quarantined"), "{err}");
    // after the quarantine window a single request is re-admitted as
    // the half-open probe; the healthy engine answers it, which closes
    // the breaker and resumes traffic
    std::thread::sleep(Duration::from_millis(80));
    let logits = client
        .infer_tenant("gold", x.clone())
        .expect("the half-open probe must be dispatched");
    assert!(!logits.is_empty());
    assert!(!server.tenant_quarantined("gold"), "probe success closes");
    assert!(client.infer_tenant("gold", x.clone()).is_ok());
    server.shutdown().unwrap();
}

#[test]
fn quota_gauge_recovers_after_a_panic_failed_job() {
    let _guard = serial();
    // regression: the per-tenant outstanding gauge must be decremented
    // on *every* terminal path, including jobs failed by a contained
    // worker panic — a leak here would ratchet the tenant toward a
    // permanent quota rejection
    let tenants = [tenant("bulk", 1.0, None)];
    let mut c = cfg(1);
    c.tenant_quota = Some(1.0);
    let plan = FaultPlan::parse("panic:0@1").unwrap();
    let server = Server::start_tenants(plan.wrap(sim()), c, table(&tenants)).unwrap();
    let client = server.client();
    let x = image();
    let bulk_id = client.tenant_id("bulk").unwrap();
    let err = client
        .infer_tenant("bulk", x.clone())
        .expect_err("batch 1 panics")
        .to_string();
    assert!(err.contains("panicked"), "{err}");
    assert_eq!(
        server.metrics().tenant_outstanding_count(bulk_id),
        0,
        "panic-failed job must release its gauge slot"
    );
    // pool recovers; a served job round-trips the gauge back to zero
    let logits = infer_tenant_until_ok(&client, "bulk", &x, 5);
    assert!(!logits.is_empty());
    assert_eq!(server.metrics().tenant_outstanding_count(bulk_id), 0);
    assert_eq!(server.metrics().tenant_quota_rejected_count(bulk_id), 0);
    server.shutdown().unwrap();
}

#[test]
fn chaos_matrix_passes_all_gates_and_emits_a_valid_record() {
    let _guard = serial();
    // the acceptance gate, in-process: all four drill scenarios must
    // pass their containment gates (chaos_matrix bails on any violated
    // invariant) and the emitted record must round-trip the schema
    let mut c = cfg(4);
    c.queue_cap = 32;
    let out = std::env::temp_dir().join(format!("ocs_it_chaos_matrix_{}.json", std::process::id()));
    let report = chaos_matrix(sim(), &c, &[], 8, 96, Some(&out)).unwrap();
    assert_eq!(report.scenarios.len(), 4, "{report:?}");
    let names: Vec<&str> = report.scenarios.iter().map(|s| s.name.as_str()).collect();
    assert_eq!(
        names,
        ["single-kill", "multi-kill", "swap-crash", "crash-loop-tenant"]
    );
    for s in &report.scenarios {
        assert!(
            s.recovered.rps >= 0.5 * s.healthy.rps,
            "{}: recovery gate: {s:?}",
            s.name
        );
    }
    let single = &report.scenarios[0];
    assert!(single.panics >= 1 && single.restarts >= 1, "{single:?}");
    let multi = &report.scenarios[1];
    assert!(multi.panics >= 2, "two workers die: {multi:?}");
    let swap = &report.scenarios[2];
    assert!(swap.swap_aborts >= 1, "{swap:?}");
    assert_eq!(swap.restarts, 0, "rollback, not respawn: {swap:?}");
    let crash = &report.scenarios[3];
    assert!(crash.quarantined >= 1, "{crash:?}");
    assert_eq!(crash.dead_workers, 0, "{crash:?}");
    let rec = BenchRecord::load(&out).unwrap();
    rec.validate().unwrap();
    assert_eq!(rec.bench, "chaos_matrix");
    assert_eq!(rec.rows.len(), 12, "4 scenarios x 3 phases");
    let _ = std::fs::remove_file(&out);
}
