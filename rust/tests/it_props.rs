//! Cross-module property tests (pure Rust, no artifacts needed):
//! invariants that tie quant + clip + ocs + stats together.

use ocs::clip::ClipMethod;
use ocs::miniprop::{check, check_n, ensure, gen_outlier_vec, gen_usize};
use ocs::ocs::{weight_ocs, SplitMode};
use ocs::quant::error::hist_quant_mse;
use ocs::quant::{fake_quant_tensor, fake_quant_val, QuantSpec};
use ocs::stats::Histogram;
use ocs::tensor::TensorF;

#[test]
fn prop_fake_quant_idempotent() {
    // Q(Q(x)) == Q(x): quantization is a projection
    check("fake-quant-idempotent", |rng| {
        let bits = gen_usize(rng, 2, 8) as u32;
        let spec = QuantSpec::new(bits);
        let thr = 0.1 + rng.next_f32() * 10.0;
        let delta = spec.delta(thr);
        let x = rng.normal() * 5.0;
        let q1 = fake_quant_val(x, delta, spec.qmax());
        let q2 = fake_quant_val(q1, delta, spec.qmax());
        ensure((q1 - q2).abs() < 1e-6, format!("{q1} vs {q2}"))
    });
}

#[test]
fn prop_fake_quant_bounded_by_threshold() {
    check("fake-quant-bounded", |rng| {
        let bits = gen_usize(rng, 2, 8) as u32;
        let spec = QuantSpec::new(bits);
        let thr = 0.1 + rng.next_f32() * 4.0;
        let data = gen_outlier_vec(rng, 1, 200);
        let t = TensorF::from_vec(&[data.len()], data).unwrap();
        let q = fake_quant_tensor(&t, thr, spec);
        ensure(
            q.max_abs() <= thr + 1e-5,
            format!("quantized max {} > threshold {thr}", q.max_abs()),
        )
    });
}

#[test]
fn prop_clip_thresholds_within_range_and_positive() {
    check_n("clip-threshold-range", 7, 32, |rng| {
        let data = gen_outlier_vec(rng, 50, 2000);
        let hist = Histogram::from_slice(&data, 512);
        if hist.count() == 0 || hist.max_abs() == 0.0 {
            return Ok(());
        }
        let bits = gen_usize(rng, 3, 8) as u32;
        let spec = QuantSpec::new(bits);
        for m in [
            ClipMethod::None,
            ClipMethod::Mse,
            ClipMethod::Aciq,
            ClipMethod::Kl,
            ClipMethod::Percentile(0.995),
        ] {
            let t = m.threshold(&hist, spec);
            ensure(
                t > 0.0 && t <= hist.max_abs() * 1.0001,
                format!("{}: t {t} out of (0, {}]", m.name(), hist.max_abs()),
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_mse_clip_never_worse_than_no_clip() {
    // by construction the sweep includes the full range, so the expected
    // histogram MSE of the MSE-optimal threshold <= MSE at max-abs
    check_n("mse-clip-optimal", 11, 32, |rng| {
        let data = gen_outlier_vec(rng, 100, 3000);
        let hist = Histogram::from_slice(&data, 512);
        if hist.count() == 0 || hist.max_abs() == 0.0 {
            return Ok(());
        }
        let spec = QuantSpec::new(gen_usize(rng, 3, 8) as u32);
        let t = ClipMethod::Mse.threshold(&hist, spec);
        let e_opt = hist_quant_mse(&hist, t, spec);
        let e_max = hist_quant_mse(&hist, hist.max_abs(), spec);
        ensure(
            e_opt <= e_max + 1e-12,
            format!("opt {e_opt} > max-range {e_max}"),
        )
    });
}

#[test]
fn prop_ocs_reduces_or_preserves_range() {
    // every OCS split halves the current max channel: the layer range is
    // non-increasing in the number of splits
    check("ocs-range-monotone", |rng| {
        let cin = gen_usize(rng, 2, 12);
        let cout = gen_usize(rng, 1, 6);
        let data = gen_outlier_vec(rng, cin * cout, cin * cout);
        let w = TensorF::from_vec(&[cin, cout], data).unwrap();
        let mut last = w.max_abs();
        for n in 1..=4usize {
            let h = weight_ocs(&w, 0, cin + 4, n, SplitMode::Naive, 0.0)
                .map_err(|e| e.to_string())?;
            let m = h.w_expanded.max_abs();
            ensure(
                m <= last + 1e-6,
                format!("range grew at n={n}: {m} > {last}"),
            )?;
            last = m;
        }
        Ok(())
    });
}

#[test]
fn prop_ocs_then_quant_beats_plain_quant_on_outlier_tensors() {
    // the paper's core claim at tensor level: with a dominant outlier,
    // OCS + linear quant (folded back) usually has lower error than
    // plain linear quant at low bits. Individual draws can go either way
    // (the split doubles the per-half rounding noise), so the property
    // is statistical: OCS must win the large majority and on average.
    let mut rng = ocs::util::rng::Rng::new(13);
    let (mut wins, mut total) = (0usize, 0usize);
    let (mut sum_plain, mut sum_ocs) = (0.0f64, 0.0f64);
    for _ in 0..60 {
        let cin = gen_usize(&mut rng, 4, 12);
        let cout = gen_usize(&mut rng, 2, 8);
        let mut data = vec![0.0f32; cin * cout];
        for v in data.iter_mut() {
            *v = rng.normal() * 0.5;
        }
        data[0] = 6.0 + rng.next_f32() * 4.0; // dominant outlier
        let w = TensorF::from_vec(&[cin, cout], data).unwrap();
        let spec = QuantSpec::new(4);

        let q_plain = fake_quant_tensor(&w, w.max_abs(), spec);
        let e_plain = w.mse(&q_plain);

        let mut h = weight_ocs(&w, 0, cin + 2, 2, SplitMode::QuantAware, 0.0).unwrap();
        let t = h.w_expanded.max_abs();
        h.w_expanded = fake_quant_tensor(&h.w_expanded, t, spec);
        let e_ocs = w.mse(&h.effective_weight(0));

        total += 1;
        if e_ocs <= e_plain {
            wins += 1;
        }
        sum_plain += e_plain;
        sum_ocs += e_ocs;
    }
    assert!(
        wins * 100 >= total * 80,
        "OCS won only {wins}/{total} outlier cases"
    );
    assert!(
        sum_ocs < sum_plain * 0.7,
        "mean OCS error {sum_ocs} not clearly below plain {sum_plain}"
    );
}

#[test]
fn prop_histogram_merge_equals_bulk_build() {
    // streaming per-batch hist + merge must agree with a one-shot build
    // on every statistic the clip methods consume
    check_n("hist-merge-consistency", 17, 32, |rng| {
        let a = gen_outlier_vec(rng, 10, 500);
        let b = gen_outlier_vec(rng, 10, 500);
        let mut ha = Histogram::from_slice(&a, 256);
        let hb = Histogram::from_slice(&b, 256);
        ha.merge(&hb);
        let mut all = a.clone();
        all.extend_from_slice(&b);
        let bulk = Histogram::from_slice(&all, 256);
        ensure(ha.count() == bulk.count(), "count")?;
        ensure(
            (ha.mean() - bulk.mean()).abs() < 1e-6,
            format!("mean {} vs {}", ha.mean(), bulk.mean()),
        )?;
        ensure((ha.max_abs() - bulk.max_abs()).abs() < 1e-6, "max_abs")?;
        // percentiles agree within re-binning error (each estimate is off
        // by at most its own bin width; merged re-binning adds one more)
        let tol = ((ha.bin_width() + bulk.bin_width()) * 2.0) as f64;
        let (pa, pb) = (ha.percentile_abs(0.9), bulk.percentile_abs(0.9));
        ensure(
            ((pa - pb) as f64).abs() <= tol,
            format!("p90: {pa} vs {pb} (tol {tol})"),
        )
    });
}

#[test]
fn prop_quant_error_decreases_with_bits() {
    check_n("bits-monotone", 19, 32, |rng| {
        let data = gen_outlier_vec(rng, 100, 2000);
        let t = TensorF::from_vec(&[data.len()], data).unwrap();
        let thr = t.max_abs().max(1e-6);
        let mut last = f64::INFINITY;
        for bits in [3u32, 5, 7, 9] {
            let q = fake_quant_tensor(&t, thr, QuantSpec::new(bits));
            let e = t.mse(&q);
            ensure(
                e <= last + 1e-12,
                format!("error grew at {bits} bits: {e} > {last}"),
            )?;
            last = e;
        }
        Ok(())
    });
}

#[test]
fn prop_fault_plan_label_round_trip() {
    // the serving layer's fault DSL: for any directive list built from
    // the supported shapes, label -> parse must be the identity (the CI
    // drills and TOML configs rely on specs surviving a render cycle)
    use ocs::serve::faults::{FaultDirective, FaultPlan};

    fn gen_tenant(rng: &mut ocs::util::rng::Rng) -> String {
        ["gold", "bulk", "lead", "t-0", "a_b", "Ocs9"][rng.below(6)].to_string()
    }

    check_n("fault-plan-round-trip", 29, 64, |rng| {
        let mut directives = Vec::new();
        for _ in 0..rng.below(6) {
            directives.push(match rng.below(6) {
                0 => FaultDirective::BuildFail {
                    worker: rng.below(8),
                    nth: 1 + rng.below(5) as u64,
                },
                1 => FaultDirective::PanicOnBatch {
                    worker: rng.below(8),
                    nth: 1 + rng.below(9) as u64,
                },
                2 => FaultDirective::SlowInfer {
                    micros: rng.below(50_000) as u64,
                },
                3 => FaultDirective::ErrorOnTenant { tenant: gen_tenant(rng) },
                4 => FaultDirective::PanicOnTenant { tenant: gen_tenant(rng) },
                _ => FaultDirective::PanicOnSync {
                    tenant: gen_tenant(rng),
                    nth: 1 + rng.below(5) as u64,
                },
            });
        }
        let plan = FaultPlan::new(directives);
        let label = plan.label();
        let back = FaultPlan::parse(&label)
            .map_err(|e| format!("own label rejected: {e}\nlabel: {label:?}"))?;
        ensure(
            back == plan,
            format!("round-trip drift via {label:?}: {back:?} vs {plan:?}"),
        )?;
        ensure(
            back.label() == label,
            format!("label not idempotent: {:?} vs {label:?}", back.label()),
        )
    });
}

#[test]
fn prop_recipe_toml_round_trip_fingerprint() {
    // serialize -> parse must be the identity on the recipe fingerprint
    // (and the canonical form behind it) for any recipe built from the
    // built-in dimensions — the emit path `ocs autotune` ships winners on
    use ocs::clip::ClipMethod;
    use ocs::model::LayerKind;
    use ocs::ocs::{OcsTarget, SplitMode};
    use ocs::pipeline::{LayerMatch, LayerOverride, LayerPolicy, LayerPos, QuantRecipe};
    use ocs::util::toml::Config;

    fn gen_clip(rng: &mut ocs::util::rng::Rng) -> ClipMethod {
        match rng.below(6) {
            0 => ClipMethod::None,
            1 => ClipMethod::Mse,
            2 => ClipMethod::Aciq,
            3 => ClipMethod::Kl,
            4 => ClipMethod::Percentile(0.999),
            _ => ClipMethod::Percentile((rng.below(1000) as f64) / 1000.0),
        }
    }
    fn gen_bits(rng: &mut ocs::util::rng::Rng) -> u32 {
        // 0 = float, else the supported 2..=16 grid range
        match rng.below(4) {
            0 => 0,
            _ => 2 + rng.below(15) as u32,
        }
    }

    check_n("recipe-toml-round-trip", 23, 64, |rng| {
        let mut r = QuantRecipe::float();
        r.w_bits = (gen_bits(rng) > 0).then(|| gen_bits(rng).max(2));
        r.a_bits = (gen_bits(rng) > 0).then(|| gen_bits(rng).max(2));
        r.w_clip = gen_clip(rng).into();
        r.a_clip = gen_clip(rng).into();
        r.ocs_ratio = (rng.below(101) as f64) / 100.0;
        r.ocs_target = if rng.below(2) == 0 { OcsTarget::Weights } else { OcsTarget::Activations };
        r.split_mode = if rng.below(2) == 0 { SplitMode::Naive } else { SplitMode::QuantAware };
        for _ in 0..rng.below(5) {
            let mut m = LayerMatch::default();
            if rng.below(2) == 0 {
                m.name_glob = Some(
                    ["fc*", "conv?", "*", "emb_?x*", "layer\"q\"", "a\\b*"][rng.below(6)]
                        .to_string(),
                );
            }
            if rng.below(3) == 0 {
                m.kind = Some([LayerKind::Conv, LayerKind::Fc, LayerKind::Embed][rng.below(3)]);
            }
            if rng.below(3) == 0 {
                m.pos = Some([LayerPos::First, LayerPos::Last, LayerPos::Edge][rng.below(3)]);
            }
            let mut p = LayerPolicy::default();
            if rng.below(4) == 0 {
                p.quantize = Some(rng.below(2) == 0);
            }
            if rng.below(2) == 0 {
                p.w_bits = Some(gen_bits(rng));
            }
            if rng.below(2) == 0 {
                p.a_bits = Some(gen_bits(rng));
            }
            if rng.below(3) == 0 {
                p.w_clip = Some(gen_clip(rng).into());
            }
            if rng.below(3) == 0 {
                p.a_clip = Some(gen_clip(rng).into());
            }
            if rng.below(3) == 0 {
                p.ocs_ratio = Some((rng.below(101) as f64) / 100.0);
            }
            if rng.below(4) == 0 {
                p.ocs_target =
                    Some(if rng.below(2) == 0 { OcsTarget::Weights } else { OcsTarget::Activations });
            }
            if rng.below(4) == 0 {
                p.split_mode =
                    Some(if rng.below(2) == 0 { SplitMode::Naive } else { SplitMode::QuantAware });
            }
            if p.is_empty() {
                // from_toml rejects policy-free tables; give it one field
                p.w_bits = Some(gen_bits(rng));
            }
            r.push_override(LayerOverride { matches: m, policy: p });
        }
        let text = r.to_toml("quant");
        let cfg = Config::parse(&text).map_err(|e| format!("emitted TOML unparseable: {e}\n{text}"))?;
        let back = QuantRecipe::from_toml(&cfg, "quant")
            .map_err(|e| format!("emitted TOML rejected: {e}\n{text}"))?;
        ensure(
            back.fingerprint() == r.fingerprint(),
            format!("fingerprint drift:\n{}\nvs\n{}\nfrom\n{text}", back.canonical(), r.canonical()),
        )
    });
}
