//! Integration tests for the sharded engine pool, driven end-to-end on
//! the synthetic backend — no AOT artifacts or PJRT needed, so these run
//! everywhere (CI included) and exercise the router, admission control,
//! deadlines, drain, and worker scaling for real.

use std::sync::{Arc, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use ocs::pipeline::ServeConfig;
use ocs::serve::backend::{EngineFactory, SimFactory, WorkerEngine};
use ocs::serve::{run_point, Server};
use ocs::tensor::TensorF;

/// These tests burn real CPU and assert on wall-clock behaviour; under
/// cargo's parallel test runner they would corrupt each other's
/// measurements (and flake the throughput-scaling gate). One
/// process-wide lock serializes the timing-sensitive ones.
static SERIAL: Mutex<()> = Mutex::new(());

fn serial() -> MutexGuard<'static, ()> {
    SERIAL.lock().unwrap_or_else(|e| e.into_inner())
}

fn sim(classes: usize, per_batch_us: u64, per_item_us: u64) -> Arc<SimFactory> {
    Arc::new(SimFactory {
        classes,
        cost_per_batch: Duration::from_micros(per_batch_us),
        cost_per_item: Duration::from_micros(per_item_us),
    })
}

fn img(seed: f32) -> TensorF {
    let data: Vec<f32> = (0..12).map(|i| seed + i as f32 * 0.25).collect();
    TensorF::from_vec(&[1, 12], data).unwrap()
}

fn cores() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

#[test]
fn zero_workers_rejected_before_any_thread_spawns() {
    let cfg = ServeConfig {
        workers: 0,
        ..ServeConfig::default()
    };
    assert!(cfg.validate().is_err());
    let err = Server::start_with(sim(10, 0, 0), cfg).unwrap_err();
    assert!(err.to_string().contains("workers"), "{err:#}");
}

#[test]
fn startup_failure_surfaces_and_joins_cleanly() {
    // PJRT path with a nonexistent artifacts dir: every worker's setup
    // fails; start must return the error, not hang or panic.
    let cfg = ServeConfig {
        workers: 3,
        ..ServeConfig::default()
    };
    let err = Server::start(
        "definitely_missing_artifacts",
        "minivgg",
        ocs::pipeline::QuantConfig::float().to_recipe(),
        cfg,
    )
    .unwrap_err();
    assert!(err.to_string().contains("worker 0 setup"), "{err:#}");
}

#[test]
fn full_queue_rejects_instead_of_hanging() {
    let _guard = serial();
    // one slow worker, queue of 1: most of a burst must be rejected fast
    let cfg = ServeConfig {
        workers: 1,
        max_batch: 1,
        max_wait: Duration::from_micros(100),
        queue_cap: 1,
        deadline: None,
        ..ServeConfig::default()
    };
    let server = Server::start_with(sim(10, 100_000, 0), cfg).unwrap();
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for c in 0..8 {
        let client = server.client();
        handles.push(std::thread::spawn(move || client.infer(img(c as f32))));
    }
    let mut ok = 0;
    let mut overloaded = 0;
    for h in handles {
        match h.join().unwrap() {
            Ok(logits) => {
                assert_eq!(logits.len(), 10);
                ok += 1;
            }
            Err(e) => {
                assert!(e.to_string().contains("overloaded"), "{e:#}");
                overloaded += 1;
            }
        }
    }
    assert!(
        t0.elapsed() < Duration::from_secs(5),
        "rejection must be immediate, not a hang"
    );
    assert!(ok >= 1, "at least the in-flight job succeeds");
    assert!(overloaded >= 1, "a burst of 8 into capacity 2 must reject");
    assert_eq!(ok + overloaded, 8, "every request got a response");
    assert_eq!(server.metrics().rejected_count(), overloaded as u64);
    server.shutdown().unwrap();
}

#[test]
fn deadline_exceeded_jobs_get_an_error_response() {
    let _guard = serial();
    // 50 ms per pass, 5 ms deadline: everything queued behind the first
    // job expires, and must be *answered*, not dropped
    let cfg = ServeConfig {
        workers: 1,
        max_batch: 1,
        max_wait: Duration::from_micros(100),
        queue_cap: 16,
        deadline: Some(Duration::from_millis(5)),
        ..ServeConfig::default()
    };
    let server = Server::start_with(sim(10, 50_000, 0), cfg).unwrap();
    let mut handles = Vec::new();
    for c in 0..4 {
        let client = server.client();
        handles.push(std::thread::spawn(move || client.infer(img(c as f32))));
    }
    let mut ok = 0;
    let mut expired = 0;
    for h in handles {
        match h.join().unwrap() {
            Ok(_) => ok += 1,
            Err(e) => {
                assert!(e.to_string().contains("deadline exceeded"), "{e:#}");
                expired += 1;
            }
        }
    }
    assert_eq!(ok + expired, 4, "every request got a response");
    assert!(expired >= 1, "jobs stuck behind a 50 ms pass must expire");
    assert!(server.metrics().aggregate().deadline_exceeded >= expired as u64);
    server.shutdown().unwrap();
}

#[test]
fn shutdown_drains_admitted_jobs() {
    let _guard = serial();
    let cfg = ServeConfig {
        workers: 2,
        max_batch: 1,
        max_wait: Duration::from_micros(100),
        queue_cap: 16,
        deadline: None,
        ..ServeConfig::default()
    };
    let server = Server::start_with(sim(10, 30_000, 0), cfg).unwrap();
    let mut handles = Vec::new();
    for c in 0..8 {
        let client = server.client();
        handles.push(std::thread::spawn(move || client.infer(img(c as f32))));
    }
    // wait until all 8 are admitted (in a queue or in flight) ...
    let t0 = Instant::now();
    while server.metrics().dispatched_count() < 8 {
        assert!(t0.elapsed() < Duration::from_secs(5), "admission stalled");
        std::thread::sleep(Duration::from_millis(1));
    }
    // ... then shut down: drain, don't drop
    server.shutdown().unwrap();
    for h in handles {
        let logits = h.join().unwrap().expect("admitted job must be answered");
        assert_eq!(logits.len(), 10);
    }
}

#[test]
fn responses_route_back_to_the_right_request() {
    let _guard = serial();
    // distinct inputs through a batching pool must come back as exactly
    // the logits the sim engine computes for that input alone
    let cfg = ServeConfig {
        workers: 2,
        max_batch: 8,
        max_wait: Duration::from_millis(2),
        queue_cap: 64,
        deadline: None,
        ..ServeConfig::default()
    };
    let factory = sim(6, 500, 100);
    let server = Server::start_with(factory.clone(), cfg).unwrap();
    let mut handles = Vec::new();
    for c in 0..16 {
        let client = server.client();
        handles.push(std::thread::spawn(move || {
            (c, client.infer(img(c as f32)).unwrap())
        }));
    }
    let mut direct_engine = factory.build(0).unwrap();
    for h in handles {
        let (c, served) = h.join().unwrap();
        let direct = direct_engine.infer(&img(c as f32)).unwrap();
        assert_eq!(served, direct.data(), "request {c} got someone else's logits");
    }
    server.shutdown().unwrap();
}

#[test]
fn pool_metrics_are_honest_after_load() {
    let _guard = serial();
    let cfg = ServeConfig {
        workers: 2,
        max_batch: 8,
        max_wait: Duration::from_millis(2),
        queue_cap: 256,
        deadline: None,
        ..ServeConfig::default()
    };
    let server = Server::start_with(sim(10, 1_000, 0), cfg).unwrap();
    let mut handles = Vec::new();
    for c in 0..4 {
        let client = server.client();
        handles.push(std::thread::spawn(move || {
            for i in 0..8 {
                client.infer(img((c * 8 + i) as f32)).unwrap();
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let agg = server.metrics().aggregate();
    assert_eq!(agg.requests, 32);
    assert_eq!(server.metrics().dispatched_count(), 32);
    assert_eq!(server.metrics().rejected_count(), 0);
    assert_eq!(server.metrics().queue_depth(), 0, "gauge returns to zero");
    assert!(agg.batches >= 1 && agg.batches <= 32);
    assert!(agg.mean_batch() >= 1.0);
    assert!(agg.mean_batch_weighted() >= agg.mean_batch() - 1e-9);
    assert_eq!(agg.batch_items_total, 32, "every request rode a batch");
    server.shutdown().unwrap();
}

/// The acceptance criterion: on real parallel hardware, 4 shards must
/// sustain strictly higher throughput than 1 on the same CPU-bound load.
#[test]
fn four_workers_beat_one_on_synthetic_load() {
    let _guard = serial();
    if cores() < 2 {
        eprintln!("SKIP: single-core machine, worker scaling unmeasurable");
        return;
    }
    // 2 ms of busy CPU per request, batching disabled: throughput is
    // compute-bound, so extra shards are the only way to go faster.
    let factory = sim(10, 0, 2_000);
    let cfg = ServeConfig {
        workers: 1,
        max_batch: 1,
        max_wait: Duration::from_micros(100),
        queue_cap: 1024,
        deadline: None,
        ..ServeConfig::default()
    };
    let p1 = run_point(factory.clone(), &cfg, 1, 48).unwrap();
    let p4 = run_point(factory, &cfg, 4, 48).unwrap();
    assert_eq!(p1.ok, p1.requests, "workers=1 load must fully succeed");
    assert_eq!(p4.ok, p4.requests, "workers=4 load must fully succeed");
    // generous margin: even 2 shared cores give ~2x on this load
    assert!(
        p4.rps > p1.rps * 1.2,
        "expected scaling: workers=1 {:.0} req/s vs workers=4 {:.0} req/s",
        p1.rps,
        p4.rps
    );
}
