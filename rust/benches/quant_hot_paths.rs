//! Hot-path microbenchmarks for the quantization toolchain (the L3
//! compute that runs per layer on every `prepare()` call): histogram
//! build, the four clip-threshold optimizers, fake-quant, and the OCS
//! transforms. These are the §Perf targets for the pure-Rust side.
//!
//! Run:  cargo bench --bench quant_hot_paths [-- <filter>]
//! Env:  OCS_BENCH_QUICK=1 for short runs.

use ocs::bench_support::Runner;
use ocs::clip::ClipMethod;
use ocs::ocs::{weight_ocs, SplitMode};
use ocs::quant::{fake_quant_tensor, QuantSpec};
use ocs::stats::Histogram;
use ocs::tensor::TensorF;
use ocs::util::rng::Rng;

fn main() {
    let mut r = Runner::from_env();
    let mut rng = Rng::new(0);

    // a realistic big layer: 512-channel FC weight (640 padded), ~330k params
    let big: Vec<f32> = (0..512 * 640).map(|_| rng.normal()).collect();
    let big_t = TensorF::from_vec(&[512, 640], big.clone()).unwrap();
    let spec4 = QuantSpec::new(4);

    r.section("histogram");
    r.bench("hist/build_330k_2048bins", || {
        let h = Histogram::from_slice(&big, 2048);
        std::hint::black_box(h.count());
    });
    let hist = Histogram::from_slice(&big, 2048);
    r.bench("hist/percentile", || {
        std::hint::black_box(hist.percentile_abs(0.99));
    });

    r.section("clip threshold optimizers (2048-bin hist, 4-bit)");
    r.bench("clip/none", || {
        std::hint::black_box(ClipMethod::None.threshold(&hist, spec4));
    });
    r.bench("clip/mse_sweep128", || {
        std::hint::black_box(ClipMethod::Mse.threshold(&hist, spec4));
    });
    r.bench("clip/aciq_analytic", || {
        std::hint::black_box(ClipMethod::Aciq.threshold(&hist, spec4));
    });
    r.bench("clip/kl_stride4", || {
        std::hint::black_box(ClipMethod::Kl.threshold(&hist, spec4));
    });
    r.bench("clip/percentile", || {
        std::hint::black_box(ClipMethod::Percentile(0.999).threshold(&hist, spec4));
    });

    r.section("fake quant");
    r.bench("quant/fake_quant_330k", || {
        std::hint::black_box(fake_quant_tensor(&big_t, 3.0, spec4).len());
    });

    r.section("OCS transforms (512ch -> 640 pad)");
    for n in [1usize, 8, 32] {
        r.bench(&format!("ocs/weight_split_n{n}"), || {
            let h = weight_ocs(&big_t, 0, 640, n, SplitMode::QuantAware, 0.01).unwrap();
            std::hint::black_box(h.active);
        });
    }
    r.bench("ocs/identity_hooks", || {
        let h = ocs::ocs::identity_hooks(&big_t, 0, 640).unwrap();
        std::hint::black_box(h.active);
    });

    r.section("end-to-end layer prepare proxy (hist + clip + quant)");
    r.bench("prepare/layer_proxy_mse", || {
        let h = Histogram::from_slice(&big, 2048);
        let t = ClipMethod::Mse.threshold(&h, spec4);
        std::hint::black_box(fake_quant_tensor(&big_t, t, spec4).len());
    });
}
