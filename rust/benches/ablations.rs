//! Ablation benches for the design choices DESIGN.md §5 calls out:
//!
//! * **knapsack vs uniform split allocation** (paper §3.4 claims the
//!   knapsack planner is "experimentally not better" — we verify on our
//!   substrate by comparing end-of-pipeline quantization error at equal
//!   budget);
//! * **QA vs naive splitting** MSE at the tensor level (Table 1's
//!   mechanism, isolated from model accuracy);
//! * **KL sweep stride** (threshold drift vs speed);
//! * **histogram bin count** (threshold stability vs build cost).
//!
//! Run:  cargo bench --bench ablations

use ocs::bench_support::Runner;
use ocs::clip::{kl, ClipMethod};
use ocs::ocs::plan::{plan_knapsack, plan_uniform, KnapsackLayer};
use ocs::ocs::{weight_ocs, SplitMode};
use ocs::quant::{fake_quant_tensor, QuantSpec};
use ocs::stats::Histogram;
use ocs::tensor::TensorF;
use ocs::util::rng::Rng;

/// Post-OCS quantization MSE of a layer set under a split plan.
fn plan_mse(layers: &[TensorF], plan: &[usize], spec: QuantSpec) -> f64 {
    let mut total = 0.0;
    for (w, &n) in layers.iter().zip(plan) {
        let cin = w.shape()[0];
        let hooks = weight_ocs(w, 0, cin + n.max(1), n, SplitMode::QuantAware, 0.0).unwrap();
        let mut active: Vec<f32> = Vec::new();
        for s in 0..hooks.active {
            active.extend(hooks.w_expanded.axis_slice(0, s).unwrap());
        }
        let t = active.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        let at = TensorF::from_vec(&[active.len()], active).unwrap();
        let q = fake_quant_tensor(&at, t, spec);
        total += at.mse(&q) * at.len() as f64;
    }
    total
}

fn main() {
    let mut r = Runner::from_env();
    let mut rng = Rng::new(3);
    let spec = QuantSpec::new(4);

    // synthetic layer set with heterogeneous outlier structure
    let layers: Vec<TensorF> = (0..6)
        .map(|i| {
            let c = 32 + i * 16;
            let mut data = rng.normal_vec(c * 64);
            // plant outliers in a few channels, heavier in later layers
            for k in 0..(1 + i) {
                data[k * 64] = 6.0 + i as f32 * 2.0;
            }
            TensorF::from_vec(&[c, 64], data).unwrap()
        })
        .collect();
    let geom: Vec<(usize, usize)> = layers
        .iter()
        .map(|w| (w.shape()[0], w.shape()[0] * 2))
        .collect();

    r.section("knapsack vs uniform allocation (paper §3.4 ablation)");
    let ratio = 0.05;
    let uplan = plan_uniform(&geom, ratio);
    let budget: usize = uplan
        .iter()
        .zip(&layers)
        .map(|(&n, w)| n * w.shape()[1] * 4)
        .sum();
    let klayers: Vec<KnapsackLayer> = layers
        .iter()
        .map(|w| KnapsackLayer {
            channels: w.shape()[0],
            capacity: w.shape()[0] * 2,
            maxes: w.max_abs_per_axis(0).unwrap(),
            bytes_per_channel: w.shape()[1] * 4,
        })
        .collect();
    let kplan = plan_knapsack(&klayers, budget);
    let u_mse = plan_mse(&layers, &uplan, spec);
    let k_mse = plan_mse(&layers, &kplan, spec);
    r.report_value("ablate/uniform_plan_mse", u_mse, "sum-sq");
    r.report_value("ablate/knapsack_plan_mse", k_mse, "sum-sq");
    r.report_value(
        "ablate/knapsack_gain_pct",
        100.0 * (u_mse - k_mse) / u_mse,
        "% (paper: ~0, not better)",
    );
    r.bench("ablate/plan_knapsack_6layers", || {
        std::hint::black_box(plan_knapsack(&klayers, budget).len());
    });

    r.section("QA vs naive split quantization error (Table 1 mechanism)");
    let w = {
        let mut d = rng.normal_vec(256 * 64);
        for k in 0..8 {
            d[k * 64] = 8.0;
        }
        TensorF::from_vec(&[256, 64], d).unwrap()
    };
    for mode in [SplitMode::Naive, SplitMode::QuantAware] {
        let hooks = weight_ocs(&w, 0, 320, 16, mode, spec.delta(8.0)).unwrap();
        let eff = hooks.effective_weight(0);
        // quantize the expanded weights, fold back, compare to original
        let t = hooks.w_expanded.max_abs();
        let mut qh = hooks.clone();
        qh.w_expanded = fake_quant_tensor(&hooks.w_expanded, t, spec);
        let qeff = qh.effective_weight(0);
        let mse = w.mse(&qeff);
        r.report_value(
            &format!("ablate/split_{}_folded_mse", mode.name()),
            mse,
            "mse",
        );
        let _ = eff;
    }

    r.section("KL stride sensitivity");
    let data: Vec<f32> = (0..100_000).map(|_| rng.laplace(1.0)).collect();
    let hist = Histogram::from_slice(&data, 2048);
    let t1 = kl::threshold_with(&hist, spec, 1);
    for stride in [1usize, 4, 16] {
        let t = kl::threshold_with(&hist, spec, stride);
        r.report_value(
            &format!("ablate/kl_stride{stride}_drift_pct"),
            100.0 * ((t - t1) / t1).abs() as f64,
            "%",
        );
        r.bench(&format!("ablate/kl_stride{stride}"), || {
            std::hint::black_box(kl::threshold_with(&hist, spec, stride));
        });
    }

    r.section("per-channel grids vs OCS (extension: how much of OCS's win do per-channel grids capture?)");
    {
        use ocs::quant::channelwise::per_channel_mse_gain;
        let mut d = rng.normal_vec(64 * 32);
        for k in 0..4 {
            d[k * 32] = 7.0; // input-channel outliers
        }
        let w = TensorF::from_vec(&[64, 32], d).unwrap();
        let (pt, pc) = per_channel_mse_gain(&w, 1, spec, ClipMethod::None);
        r.report_value("ablate/per_tensor_mse", pt, "mse");
        r.report_value("ablate/per_channel_mse", pc, "mse");
        let hooks = weight_ocs(&w, 0, 80, 4, SplitMode::QuantAware, 0.0).unwrap();
        let t = hooks.w_expanded.max_abs();
        let q = fake_quant_tensor(&hooks.w_expanded, t, spec);
        let mut qh = hooks.clone();
        qh.w_expanded = q;
        r.report_value("ablate/ocs_folded_mse", w.mse(&qh.effective_weight(0)), "mse");
        r.bench("ablate/per_channel_quant_64x32", || {
            std::hint::black_box(per_channel_mse_gain(&w, 1, spec, ClipMethod::None).1);
        });
    }

    r.section("histogram bins: threshold stability (MSE method)");
    let t_ref = ClipMethod::Mse.threshold(&Histogram::from_slice(&data, 8192), spec);
    for bins in [256usize, 1024, 2048, 8192] {
        let h = Histogram::from_slice(&data, bins);
        let t = ClipMethod::Mse.threshold(&h, spec);
        r.report_value(
            &format!("ablate/mse_bins{bins}_drift_pct"),
            100.0 * ((t - t_ref) / t_ref).abs() as f64,
            "%",
        );
    }
}
