//! Rebar-style hot-path benchmark harness for the quantization compute
//! kernels, tracking the serial-vs-parallel perf trajectory PR over PR.
//!
//! Four tracked hot paths, each measured at fixed shapes against the
//! pre-kernels serial implementation (kept verbatim in [`baseline`]):
//!
//! * `calib_stats`    — per-layer calibration statistics over a batch set
//! * `perchan_quant`  — per-output-channel threshold search + fake-quant
//! * `kl_sweep`       — the KL clip-threshold sweep (stride 4 vs stride 1)
//! * `ocs_transform`  — greedy weight-OCS splitting (fused vs generic ops)
//!
//! Before timing, every fused/parallel variant is checked bit-identical
//! to its serial reference; on machines with 4+ threads the harness
//! then *asserts* the parallel per-channel quantizer beats the pre-PR
//! serial path by >= 2x (the acceptance bar). `--no-assert` or
//! `OCS_BENCH_NO_ASSERT=1` downgrades assertions to warnings.
//!
//! Run:  cargo bench --bench hotpath [-- <filter>] [--shapes small|full]
//!       [--json PATH] [--no-assert]
//! Env:  OCS_BENCH_QUICK=1 (short runs), OCS_BENCH_THREADS=1,2,4
//!
//! `--json` writes `BENCH_quant.json`, a versioned
//! [`ocs::bench_record::BenchRecord`] (same format as `BENCH_native.json`
//! / `BENCH_serving.json`); CI validates it with `ocs bench check`,
//! uploads it as an artifact, and `ocs bench diff` gates it against the
//! committed baseline in `records/`.

use std::path::PathBuf;

use ocs::bench_record::BenchRecord;
use ocs::bench_support::{BenchStats, CaseRecord, Runner};
use ocs::clip::ClipMethod;
use ocs::kernels::pool;
use ocs::kernels::stats as kstats;
use ocs::ocs::SplitMode;
use ocs::quant::channelwise::fake_quant_per_channel_with;
use ocs::quant::QuantSpec;
use ocs::stats::Histogram;
use ocs::tensor::TensorF;
use ocs::util::rng::Rng;

/// The pre-kernels implementations, kept verbatim as the fixed baseline
/// every future PR is measured against (rebar's "defined rival").
mod baseline {
    use ocs::clip::ClipMethod;
    use ocs::ocs::split::split_value;
    use ocs::ocs::{identity_hooks, OcsHooks, SplitMode};
    use ocs::quant::{fake_quant_slice, QuantSpec};
    use ocs::stats::Histogram;
    use ocs::tensor::TensorF;

    /// Pre-PR per-channel quantizer: materializes an `axis_slice` Vec
    /// per channel, builds a 512-bin histogram on the copy, quantizes
    /// channel-by-channel on one thread.
    pub fn fake_quant_per_channel(
        w: &TensorF,
        cout_axis: usize,
        spec: QuantSpec,
        clip: ClipMethod,
    ) -> (TensorF, Vec<f32>) {
        let (outer, alen, inner) = w.axis_geometry(cout_axis).expect("axis");
        let mut out = w.clone();
        let mut thresholds = Vec::with_capacity(alen);
        let qmax = spec.qmax();
        for c in 0..alen {
            let slice = w.axis_slice(cout_axis, c).expect("channel");
            let hist = Histogram::from_slice(&slice, 512);
            let t = clip.threshold(&hist, spec);
            thresholds.push(t);
            let delta = spec.delta(t.max(1e-12));
            let data = out.data_mut();
            for o in 0..outer {
                let base = (o * alen + c) * inner;
                fake_quant_slice(&mut data[base..base + inner], delta, qmax);
            }
        }
        (out, thresholds)
    }

    /// Pre-PR calibration statistics: streaming histogram sweep, then a
    /// channel-max sweep, then a modulo-indexed outlier-count sweep.
    pub fn layer_stats(batches: &[TensorF], pct: f64) -> (Histogram, Vec<f32>, Vec<u64>) {
        let mut hist = Histogram::new(2048, 1.0);
        for b in batches {
            hist.observe_all(b.data());
        }
        let thr = hist.percentile_abs(pct);
        let c = *batches[0].shape().last().unwrap();
        let mut chmax = vec![0.0f32; c];
        let mut counts = vec![0u64; c];
        for b in batches {
            let axis = b.rank() - 1;
            for (m, cm) in chmax.iter_mut().zip(b.max_abs_per_axis(axis).unwrap()) {
                *m = m.max(cm);
            }
            for (i, &v) in b.data().iter().enumerate() {
                if v.abs() > thr {
                    counts[i % c] += 1;
                }
            }
        }
        (hist, chmax, counts)
    }

    /// Pre-PR weight OCS: generic tensor ops per split (copy channel,
    /// rewrite channel, recompute two channel maxima — four sweeps).
    pub fn weight_ocs_generic(
        w: &TensorF,
        cin_axis: usize,
        cin_pad: usize,
        n_splits: usize,
        mode: SplitMode,
        delta: f32,
    ) -> OcsHooks {
        let mut hooks = identity_hooks(w, cin_axis, cin_pad).unwrap();
        let mut maxes: Vec<f32> = (0..hooks.active)
            .map(|i| hooks.w_expanded.axis_max_abs(cin_axis, i).unwrap())
            .collect();
        for _ in 0..n_splits {
            if hooks.active >= cin_pad {
                break;
            }
            let (src, _) = maxes
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .expect("at least one channel");
            let dst = hooks.active;
            hooks
                .w_expanded
                .axis_copy_with(cin_axis, src, dst, |v| split_value(v, delta, mode).1)
                .unwrap();
            hooks
                .w_expanded
                .axis_map_mut(cin_axis, src, |v| *v = split_value(*v, delta, mode).0)
                .unwrap();
            hooks.idx.data_mut()[dst] = hooks.idx.data()[src];
            hooks.dscale.data_mut()[dst] = hooks.dscale.data()[src];
            hooks.dbias.data_mut()[dst] = hooks.dbias.data()[src];
            maxes[src] = hooks.w_expanded.axis_max_abs(cin_axis, src).unwrap();
            maxes.push(hooks.w_expanded.axis_max_abs(cin_axis, dst).unwrap());
            hooks.splits.push((src, dst));
            hooks.active += 1;
        }
        hooks
    }
}

struct Opts {
    filter: Option<String>,
    shapes: String,
    json: Option<PathBuf>,
    no_assert: bool,
}

fn parse_opts() -> Opts {
    let mut o = Opts {
        filter: None,
        shapes: "full".to_string(),
        json: None,
        no_assert: std::env::var("OCS_BENCH_NO_ASSERT").is_ok(),
    };
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--json" => o.json = args.next().map(PathBuf::from),
            "--shapes" => {
                if let Some(v) = args.next() {
                    o.shapes = v;
                }
            }
            "--no-assert" => o.no_assert = true,
            "--bench" | "bench" => {}
            other if !other.starts_with("--") => o.filter = Some(other.to_string()),
            _ => {}
        }
    }
    o
}

fn thread_sweep() -> Vec<usize> {
    let avail = pool::available();
    let requested: Vec<usize> = match std::env::var("OCS_BENCH_THREADS") {
        Ok(list) => list
            .split(',')
            .filter_map(|s| s.trim().parse().ok())
            .collect(),
        Err(_) => vec![1, 2, 4, 8],
    };
    // dedup by actual participant count — asking for 8 threads on a
    // 2-core box measures the same thing as asking for 2
    let mut sweep = Vec::new();
    for t in requested {
        let actual = t.clamp(1, avail);
        if !sweep.contains(&actual) {
            sweep.push(actual);
        }
    }
    if sweep.is_empty() {
        sweep.push(1);
    }
    sweep.sort_unstable();
    sweep
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

fn record(
    cases: &mut Vec<CaseRecord>,
    name: &str,
    shape: String,
    threads: usize,
    stats: &BenchStats,
    items: f64,
    serial_mean_ns: f64,
) {
    let speedup = if stats.mean_ns > 0.0 {
        serial_mean_ns / stats.mean_ns
    } else {
        0.0
    };
    cases.push(CaseRecord::from_stats(
        name,
        &shape,
        threads,
        items / (stats.mean_ns / 1e9) / 1e6,
        speedup,
        stats,
    ));
}

fn main() {
    let opts = parse_opts();
    let mut r = Runner::with_filter(opts.filter.clone());
    let sweep = thread_sweep();
    let avail = pool::available();
    let mut cases: Vec<CaseRecord> = Vec::new();
    println!(
        "hot-path harness: shapes={} threads available={} sweep={:?}",
        opts.shapes, avail, sweep
    );

    let small = opts.shapes == "small";
    let spec = QuantSpec::new(4);
    let clip = ClipMethod::Mse;

    // ---- per-channel quantization --------------------------------------
    // acceptance shape: >= 256 output channels
    let perchan_shapes: Vec<(usize, usize)> = if small {
        vec![(256, 256)]
    } else {
        vec![(256, 1024), (512, 768)]
    };
    // best parallel speedup vs the pre-PR serial path, per shape
    let mut perchan_best: Option<(String, usize, f64)> = None;
    let mut perchan_vs_t1_best: f64 = 0.0;
    for &(c, k) in &perchan_shapes {
        let mut rng = Rng::new(7);
        let mut data = rng.normal_vec(c * k);
        for i in 0..k {
            data[3 * k + i] *= 8.0; // a hot channel, like real weights
        }
        let w = TensorF::from_vec(&[c, k], data).unwrap();
        let shape = format!("{c}x{k}");
        let items = (c * k) as f64;

        // correctness first: fused serial == pre-PR serial == fused parallel
        let (q_old, t_old) = baseline::fake_quant_per_channel(&w, 0, spec, clip);
        let (q1, t1) = fake_quant_per_channel_with(&w, 0, spec, clip, 1);
        assert_eq!(bits(q_old.data()), bits(q1.data()), "fused != pre-PR serial");
        assert_eq!(bits(&t_old), bits(&t1));
        let tmax = *sweep.last().unwrap();
        let (qn, tn) = fake_quant_per_channel_with(&w, 0, spec, clip, tmax);
        assert_eq!(bits(q1.data()), bits(qn.data()), "parallel != serial");
        assert_eq!(bits(&t1), bits(&tn));

        let old = r.bench(&format!("perchan_quant/old_serial/{shape}"), || {
            let (q, _) = baseline::fake_quant_per_channel(&w, 0, spec, clip);
            std::hint::black_box(q.len());
        });
        let old_ns = old.as_ref().map(|s| s.mean_ns);
        if let Some(s) = &old {
            record(
                &mut cases,
                "perchan_quant/old_serial",
                shape.clone(),
                1,
                &s,
                items,
                s.mean_ns,
            );
        }
        let mut t1_ns = None;
        for &t in &sweep {
            let stats = r.bench(&format!("perchan_quant/fused_t{t}/{shape}"), || {
                let (q, _) = fake_quant_per_channel_with(&w, 0, spec, clip, t);
                std::hint::black_box(q.len());
            });
            if let (Some(s), Some(old_ns)) = (&stats, old_ns) {
                record(
                    &mut cases,
                    &format!("perchan_quant/fused_t{t}"),
                    shape.clone(),
                    t,
                    &s,
                    items,
                    old_ns,
                );
                if t == 1 {
                    t1_ns = Some(s.mean_ns);
                }
                let speedup = old_ns / s.mean_ns;
                if t > 1 {
                    if perchan_best.as_ref().map(|b| speedup > b.2).unwrap_or(true) {
                        perchan_best = Some((shape.clone(), t, speedup));
                    }
                    if let Some(t1_ns) = t1_ns {
                        perchan_vs_t1_best = perchan_vs_t1_best.max(t1_ns / s.mean_ns);
                    }
                }
            }
        }
    }

    // ---- calibration statistics ----------------------------------------
    let (nb, rows, cc) = if small { (4, 32, 128) } else { (8, 64, 256) };
    {
        let mut rng = Rng::new(9);
        let batches: Vec<TensorF> = (0..nb)
            .map(|_| TensorF::from_vec(&[rows, cc], rng.normal_vec(rows * cc)).unwrap())
            .collect();
        let shape = format!("{nb}x{rows}x{cc}");
        let items = (nb * rows * cc) as f64;

        // determinism: serial == parallel on the fused path
        let s1 = kstats::layer_stats(&batches, 2048, 0.99, 1);
        let sn = kstats::layer_stats(&batches, 2048, 0.99, *sweep.last().unwrap());
        assert_eq!(s1.hist.counts(), sn.hist.counts(), "calib parallel != serial");
        assert_eq!(bits(&s1.channel_max), bits(&sn.channel_max));
        assert_eq!(s1.outlier_counts, sn.outlier_counts);

        let old = r.bench(&format!("calib_stats/old_serial/{shape}"), || {
            let (h, _, _) = baseline::layer_stats(&batches, 0.99);
            std::hint::black_box(h.count());
        });
        let old_ns = old.as_ref().map(|s| s.mean_ns);
        if let Some(s) = &old {
            record(
                &mut cases,
                "calib_stats/old_serial",
                shape.clone(),
                1,
                &s,
                items,
                s.mean_ns,
            );
        }
        for &t in &sweep {
            let stats = r.bench(&format!("calib_stats/fused_t{t}/{shape}"), || {
                let s = kstats::layer_stats(&batches, 2048, 0.99, t);
                std::hint::black_box(s.hist.count());
            });
            if let (Some(s), Some(old_ns)) = (&stats, old_ns) {
                record(
                    &mut cases,
                    &format!("calib_stats/fused_t{t}"),
                    shape.clone(),
                    t,
                    &s,
                    items,
                    old_ns,
                );
            }
        }
    }

    // ---- KL threshold sweep --------------------------------------------
    {
        let mut rng = Rng::new(11);
        let n = if small { 60_000 } else { 200_000 };
        let data: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let hist = Histogram::from_slice(&data, 2048);
        let shape = "2048bins".to_string();
        let stride1 = r.bench("kl_sweep/stride1", || {
            std::hint::black_box(ocs::clip::kl::threshold_with(&hist, spec, 1));
        });
        let s1_ns = stride1.as_ref().map(|s| s.mean_ns);
        if let Some(s) = &stride1 {
            record(
                &mut cases,
                "kl_sweep/stride1",
                shape.clone(),
                1,
                &s,
                2048.0,
                s.mean_ns,
            );
        }
        let stride4 = r.bench("kl_sweep/stride4", || {
            std::hint::black_box(ocs::clip::kl::threshold_with(&hist, spec, 4));
        });
        if let (Some(s), Some(s1_ns)) = (&stride4, s1_ns) {
            record(
                &mut cases,
                "kl_sweep/stride4",
                shape.clone(),
                1,
                &s,
                2048.0,
                s1_ns,
            );
        }
    }

    // ---- OCS transform --------------------------------------------------
    {
        let (c, k) = if small { (256, 256) } else { (512, 512) };
        let n_splits = 32;
        let mut rng = Rng::new(13);
        let w = TensorF::from_vec(&[c, k], rng.normal_vec(c * k)).unwrap();
        let shape = format!("{c}x{k}+{n_splits}");
        let items = (c * k) as f64;
        let delta = 0.01f32;

        // correctness: fused split == generic-op split, bit for bit
        let pad = c + n_splits;
        let mode = SplitMode::QuantAware;
        let fused = ocs::ocs::weight_ocs(&w, 0, pad, n_splits, mode, delta).unwrap();
        let generic = baseline::weight_ocs_generic(&w, 0, pad, n_splits, mode, delta);
        assert_eq!(
            bits(fused.w_expanded.data()),
            bits(generic.w_expanded.data()),
            "fused OCS split != generic ops"
        );
        assert_eq!(fused.splits, generic.splits);

        let old = r.bench(&format!("ocs_transform/old_generic/{shape}"), || {
            let h = baseline::weight_ocs_generic(&w, 0, pad, n_splits, mode, delta);
            std::hint::black_box(h.active);
        });
        let old_ns = old.as_ref().map(|s| s.mean_ns);
        if let Some(s) = &old {
            record(
                &mut cases,
                "ocs_transform/old_generic",
                shape.clone(),
                1,
                &s,
                items,
                s.mean_ns,
            );
        }
        let fused_stats = r.bench(&format!("ocs_transform/fused/{shape}"), || {
            let h = ocs::ocs::weight_ocs(&w, 0, pad, n_splits, mode, delta).unwrap();
            std::hint::black_box(h.active);
        });
        if let (Some(s), Some(old_ns)) = (&fused_stats, old_ns) {
            record(
                &mut cases,
                "ocs_transform/fused",
                shape.clone(),
                1,
                &s,
                items,
                old_ns,
            );
        }
    }

    // ---- verdicts --------------------------------------------------------
    let mut failures: Vec<String> = Vec::new();
    if let Some((shape, t, speedup)) = &perchan_best {
        println!(
            "\nperchan_quant: best parallel speedup vs pre-PR serial = {speedup:.2}x \
             (shape {shape}, {t} threads; {perchan_vs_t1_best:.2}x vs fused serial)"
        );
        if avail >= 4 && *speedup < 2.0 {
            failures.push(format!(
                "parallel per-channel quant only {speedup:.2}x vs pre-PR serial (need >= 2x at 4+ threads)"
            ));
        }
        if avail >= 4 && perchan_vs_t1_best > 0.0 && perchan_vs_t1_best < 1.2 {
            failures.push(format!(
                "parallel per-channel quant only {perchan_vs_t1_best:.2}x vs its own serial run"
            ));
        }
    }
    if let Some(path) = &opts.json {
        let rec = BenchRecord::from_cases("quant", "cpu", avail, &cases);
        rec.write(path).expect("write BENCH_quant.json");
        println!("wrote {} ({} cases)", path.display(), cases.len());
    }
    if !failures.is_empty() {
        if opts.no_assert {
            for f in &failures {
                println!("WARN (no-assert): {f}");
            }
        } else {
            for f in &failures {
                eprintln!("FAIL: {f}");
            }
            std::process::exit(1);
        }
    }
}
