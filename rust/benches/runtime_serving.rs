//! Runtime + serving benchmarks (L3 hot path): PJRT execute latency per
//! batch size, input-packing overhead, and dynamic-batcher throughput
//! under open-loop load. The paper's deployment claim is "negligible
//! overhead" (§5.4 + §3.5) — these benches quantify the serving cost of
//! the OCS hooks (channel_dup + padded weights) vs the identity path.
//!
//! Run:  cargo bench --bench runtime_serving [-- <filter>]

use std::time::Duration;

use ocs::bench_support::Runner;
use ocs::clip::ClipMethod;
use ocs::model::store::WeightStore;
use ocs::model::ModelSpec;
use ocs::pipeline::{self, QuantConfig};
use ocs::runtime::{Engine, Input, Inputs};
use ocs::serve::{ServeConfig, Server};
use ocs::tensor::TensorF;
use ocs::train::data;

fn main() -> anyhow::Result<()> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping runtime_serving bench: run `make artifacts` first");
        return Ok(());
    }
    let mut r = Runner::from_env();
    let model = "minivgg";
    let spec = ModelSpec::load_named("artifacts", model)?;
    let (ws, _) = WeightStore::load_best(&spec)?;
    let engine = Engine::cpu()?;

    // identity (float) and OCS-quantized preparations
    let prep_float = pipeline::prepare(&spec, &ws, None, &QuantConfig::float())?;
    let prep_ocs = pipeline::prepare(
        &spec,
        &ws,
        None,
        &QuantConfig::weights_only(5, ClipMethod::Mse, 0.05),
    )?;

    r.section("PJRT execute latency by batch (float hooks)");
    for b in [1usize, 8, 32, 128] {
        let art = spec.fwd_for_batch(b)?;
        if art.batch != b {
            continue;
        }
        let exe = engine.load(art)?;
        let imgs = data::synth_images(b, 5);
        let mut inputs: Inputs = Default::default();
        prep_float.insert_inputs(&mut inputs);
        inputs.insert("x".into(), Input::F32(imgs.x.clone()));
        r.bench(&format!("execute/fwd_b{b}"), || {
            let out = exe.execute(&inputs).unwrap();
            std::hint::black_box(out.get("logits").unwrap().len());
        });
    }

    r.section("OCS-hook overhead at fixed batch 32 (paper: negligible)");
    let art = spec.fwd_for_batch(32)?;
    let exe = engine.load(art)?;
    let imgs = data::synth_images(32, 5);
    for (tag, prep) in [("identity", &prep_float), ("ocs_r0.05", &prep_ocs)] {
        let mut inputs: Inputs = Default::default();
        prep.insert_inputs(&mut inputs);
        inputs.insert("x".into(), Input::F32(imgs.x.clone()));
        r.bench(&format!("execute/b32_{tag}"), || {
            let out = exe.execute(&inputs).unwrap();
            std::hint::black_box(out.get("logits").unwrap().len());
        });
    }

    r.section("input packing (tensor -> literal)");
    let mut inputs: Inputs = Default::default();
    prep_ocs.insert_inputs(&mut inputs);
    r.bench("pack/insert_inputs_clone", || {
        let mut m: Inputs = Default::default();
        prep_ocs.insert_inputs(&mut m);
        std::hint::black_box(m.len());
    });

    r.section("dynamic-batching server throughput");
    for (tag, clients) in [("c1", 1usize), ("c8", 8), ("c32", 32)] {
        let server = Server::start(
            "artifacts",
            model,
            QuantConfig::weights_only(5, ClipMethod::Mse, 0.02),
            ServeConfig {
                max_batch: 32,
                max_wait: Duration::from_millis(2),
                queue_cap: 2048,
            },
        )?;
        let imgs = data::synth_images(64, 6);
        let row = imgs.x.len() / imgs.len();
        let xdata = std::sync::Arc::new(imgs.x.data().to_vec());
        let t0 = std::time::Instant::now();
        let per = 256usize / clients.min(256);
        let mut handles = Vec::new();
        for c in 0..clients {
            let client = server.client();
            let xdata = xdata.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..per {
                    let idx = (c * per + i) % 64;
                    let x = TensorF::from_vec(
                        &[1, 16, 16, 3],
                        xdata[idx * row..(idx + 1) * row].to_vec(),
                    )
                    .unwrap();
                    client.infer(x).unwrap();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let n = clients * per;
        let rps = n as f64 / t0.elapsed().as_secs_f64();
        r.report_value(&format!("serve/throughput_{tag}"), rps, "req/s");
        r.report_value(
            &format!("serve/mean_batch_{tag}"),
            server.metrics().mean_batch(),
            "imgs/batch",
        );
        server.shutdown()?;
    }
    Ok(())
}
