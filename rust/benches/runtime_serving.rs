//! Runtime + serving benchmarks (L3 hot path): PJRT execute latency per
//! batch size, input-packing overhead, and engine-pool throughput swept
//! over worker counts. The paper's deployment claim is "negligible
//! overhead" (§5.4 + §3.5) — these benches quantify the serving cost of
//! the OCS hooks (channel_dup + padded weights) vs the identity path,
//! and how the pool scales once the engine is sharded per thread.
//!
//! The worker sweep runs twice: on the synthetic backend (no artifacts
//! needed — this is the record CI accumulates as BENCH_serving.json) and,
//! when artifacts exist, on the real PJRT stack.
//!
//! Run:  cargo bench --bench runtime_serving [-- <filter>]

use std::sync::Arc;
use std::time::Duration;

use ocs::bench_support::Runner;
use ocs::clip::ClipMethod;
use ocs::model::store::WeightStore;
use ocs::model::ModelSpec;
use ocs::pipeline::{self, QuantConfig, ServeConfig};
use ocs::runtime::{Engine, Input, Inputs};
use ocs::serve::backend::{EngineFactory, PjrtFactory, SimFactory};
use ocs::serve::{run_point, sweep_json, SweepPoint};
use ocs::train::data;

const SWEEP: [usize; 3] = [1, 2, 4];

fn pool_sweep(
    r: &mut Runner,
    tag: &str,
    factory: Arc<dyn EngineFactory>,
    cfg: &ServeConfig,
    requests: usize,
) -> anyhow::Result<Vec<SweepPoint>> {
    let mut points = Vec::new();
    for &w in &SWEEP {
        if !r.enabled(&format!("serve/{tag}_w{w}")) {
            continue;
        }
        let p = run_point(factory.clone(), cfg, w, requests)?;
        r.report_value(&format!("serve/{tag}_w{w}_throughput"), p.rps, "req/s");
        r.report_value(&format!("serve/{tag}_w{w}_p99"), p.p99_ms, "ms");
        r.report_value(&format!("serve/{tag}_w{w}_mean_batch"), p.mean_batch, "req/batch");
        points.push(p);
    }
    Ok(points)
}

fn main() -> anyhow::Result<()> {
    let mut r = Runner::from_env();
    let quick = std::env::var("OCS_BENCH_QUICK").is_ok();

    // ---- engine-pool worker sweep, synthetic backend (runs everywhere)
    r.section("engine-pool worker sweep (synthetic backend)");
    let sim_cfg = ServeConfig {
        workers: 1,
        max_batch: 8,
        max_wait: Duration::from_micros(500),
        queue_cap: 4096,
        deadline: None,
        ..ServeConfig::default()
    };
    let sim_points = pool_sweep(
        &mut r,
        "sim",
        Arc::new(SimFactory::default()),
        &sim_cfg,
        if quick { 128 } else { 1024 },
    )?;
    if !sim_points.is_empty() {
        std::fs::write("BENCH_serving.json", sweep_json("sim", &sim_points))?;
        println!("wrote BENCH_serving.json ({} sweep points)", sim_points.len());
    }

    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping PJRT benches: run `make artifacts` first");
        return Ok(());
    }
    let model = "minivgg";
    let spec = ModelSpec::load_named("artifacts", model)?;
    let (ws, _) = WeightStore::load_best(&spec)?;
    let engine = Engine::cpu()?;

    // identity (float) and OCS-quantized preparations
    let prep_float = pipeline::prepare(&spec, &ws, None, &QuantConfig::float())?;
    let prep_ocs = pipeline::prepare(
        &spec,
        &ws,
        None,
        &QuantConfig::weights_only(5, ClipMethod::Mse, 0.05),
    )?;

    r.section("PJRT execute latency by batch (float hooks)");
    for b in [1usize, 8, 32, 128] {
        let art = spec.fwd_for_batch(b)?;
        if art.batch != b {
            continue;
        }
        let exe = engine.load(art)?;
        let imgs = data::synth_images(b, 5);
        let mut inputs: Inputs = Default::default();
        prep_float.insert_inputs(&mut inputs);
        inputs.insert("x".into(), Input::F32(imgs.x.clone()));
        r.bench(&format!("execute/fwd_b{b}"), || {
            let out = exe.execute(&inputs).unwrap();
            std::hint::black_box(out.get("logits").unwrap().len());
        });
    }

    r.section("OCS-hook overhead at fixed batch 32 (paper: negligible)");
    let art = spec.fwd_for_batch(32)?;
    let exe = engine.load(art)?;
    let imgs = data::synth_images(32, 5);
    for (tag, prep) in [("identity", &prep_float), ("ocs_r0.05", &prep_ocs)] {
        let mut inputs: Inputs = Default::default();
        prep.insert_inputs(&mut inputs);
        inputs.insert("x".into(), Input::F32(imgs.x.clone()));
        r.bench(&format!("execute/b32_{tag}"), || {
            let out = exe.execute(&inputs).unwrap();
            std::hint::black_box(out.get("logits").unwrap().len());
        });
    }

    r.section("input packing (tensor -> literal)");
    let mut inputs: Inputs = Default::default();
    prep_ocs.insert_inputs(&mut inputs);
    r.bench("pack/insert_inputs_clone", || {
        let mut m: Inputs = Default::default();
        prep_ocs.insert_inputs(&mut m);
        std::hint::black_box(m.len());
    });

    // ---- engine-pool worker sweep over the real PJRT stack
    r.section("engine-pool worker sweep (PJRT backend)");
    let pjrt_factory = Arc::new(PjrtFactory {
        artifacts_dir: "artifacts".to_string(),
        model: model.to_string(),
        recipe: QuantConfig::weights_only(5, ClipMethod::Mse, 0.02).to_recipe(),
        max_batch: 32,
    });
    let label = pjrt_factory.label();
    let pjrt_cfg = ServeConfig {
        workers: 1,
        max_batch: 32,
        max_wait: Duration::from_millis(2),
        queue_cap: 2048,
        deadline: None,
        ..ServeConfig::default()
    };
    let pjrt_points = pool_sweep(
        &mut r,
        "pjrt",
        pjrt_factory,
        &pjrt_cfg,
        if quick { 128 } else { 512 },
    )?;
    if !pjrt_points.is_empty() {
        std::fs::write("BENCH_serving_pjrt.json", sweep_json(&label, &pjrt_points))?;
        println!(
            "wrote BENCH_serving_pjrt.json ({} sweep points)",
            pjrt_points.len()
        );
    }
    Ok(())
}
