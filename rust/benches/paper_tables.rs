//! One bench per paper table/figure: runs the exact regeneration code in
//! reduced (`--quick`) form and reports wall time per table. This is the
//! "can a user actually reproduce the evaluation" check, exercised
//! end-to-end (artifacts + trained-or-init weights + PJRT).
//!
//! Run:  cargo bench --bench paper_tables [-- <filter>]
//! Requires `make artifacts` (and ideally `ocs train --model all`).

use std::time::Instant;

use ocs::tables::TableCtx;

fn main() {
    let filter: Option<String> = std::env::args()
        .skip(1)
        .find(|a| !a.starts_with("--") && a != "bench");
    // OCS_BENCH_QUICK bounds the run to the fast tables (the full sweep
    // is minutes per table; use `ocs table --id all` for the real thing)
    let quick_env = std::env::var("OCS_BENCH_QUICK").is_ok();
    let ids: &[&str] = if quick_env {
        &["fig1", "4", "5"]
    } else {
        &["fig1", "1", "2", "3", "4", "5", "6"]
    };
    let ids = ids.iter().copied();
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping paper_tables bench: run `make artifacts` first");
        return;
    }
    let results = "results/bench";
    let ctx = match TableCtx::new("artifacts", results, true) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("cannot init table context: {e:#}");
            return;
        }
    };
    println!("paper-table regeneration (quick mode, output under {results}/)");
    for id in ids {
        if let Some(f) = &filter {
            if !id.contains(f.as_str()) {
                continue;
            }
        }
        let t0 = Instant::now();
        match ctx.run(id) {
            Ok(()) => println!(
                ">>> table {id:<5} regenerated in {:.2}s",
                t0.elapsed().as_secs_f64()
            ),
            Err(e) => println!(">>> table {id:<5} FAILED: {e:#}"),
        }
    }
}
