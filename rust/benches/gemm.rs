//! Rebar-style tracked harness for the native integer GEMM — the
//! datapath every future perf PR optimizes against.
//!
//! The fixed rival is the naive serial i8×i8→i32 triple loop, kept
//! verbatim in [`baseline`]. Before anything is timed, the packed
//! serial kernel, the packed parallel kernel at every swept width, and
//! the fused dequant epilogue are all verified **exactly equal** to
//! that baseline (integer arithmetic — any mismatch is a hard failure,
//! not noise). On machines with 4+ threads the harness then asserts the
//! packed parallel kernel beats the naive serial baseline by >= 2x.
//!
//! Run:  cargo bench --bench gemm [-- <filter>] [--shapes small|full]
//!       [--json PATH] [--no-assert]
//! Env:  OCS_BENCH_QUICK=1 (short runs), OCS_BENCH_THREADS=1,2,4,
//!       OCS_BENCH_NO_ASSERT=1
//!
//! `--json` writes `BENCH_native.json`, a versioned
//! [`ocs::bench_record::BenchRecord`] (same format as `BENCH_quant.json`
//! / `BENCH_serving.json`); CI's native-smoke job validates it with
//! `ocs bench check`, uploads it, and `ocs bench diff` gates it against
//! the committed baseline in `records/`.

use std::path::PathBuf;

use ocs::bench_record::BenchRecord;
use ocs::bench_support::{BenchStats, CaseRecord, Runner};
use ocs::clip::ClipMethod;
use ocs::kernels::gemm::{self, PackedB};
use ocs::kernels::pool;
use ocs::pipeline::{self, QuantConfig, QuantRecipe};
use ocs::runtime::native::{native_calibrate, synthetic_mlp, NativeExecutable};
use ocs::util::rng::Rng;

/// The pre-PR execution story, kept verbatim: no packing, no blocking,
/// no threads — the defined rival every record is measured against.
mod baseline {
    /// Naive serial i8 GEMM, i32 accumulators.
    pub fn gemm_i8_naive(a: &[i8], b: &[i8], m: usize, k: usize, n: usize) -> Vec<i32> {
        let mut out = vec![0i32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0i32;
                for kk in 0..k {
                    acc += a[i * k + kk] as i32 * b[kk * n + j] as i32;
                }
                out[i * n + j] = acc;
            }
        }
        out
    }

    /// Naive serial dequant epilogue over a separate i32 matrix (the
    /// unfused two-pass shape the packed kernel fuses away).
    pub fn dequant_naive(acc: &[i32], m: usize, n: usize, scales: &[f32], bias: &[f32]) -> Vec<f32> {
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                out[i * n + j] = acc[i * n + j] as f32 * scales[j] + bias[j];
            }
        }
        out
    }
}

struct Opts {
    filter: Option<String>,
    shapes: String,
    json: Option<PathBuf>,
    no_assert: bool,
}

fn parse_opts() -> Opts {
    let mut o = Opts {
        filter: None,
        shapes: "full".to_string(),
        json: None,
        no_assert: std::env::var("OCS_BENCH_NO_ASSERT").is_ok(),
    };
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--json" => o.json = args.next().map(PathBuf::from),
            "--shapes" => {
                if let Some(v) = args.next() {
                    o.shapes = v;
                }
            }
            "--no-assert" => o.no_assert = true,
            "--bench" | "bench" => {}
            other if !other.starts_with("--") => o.filter = Some(other.to_string()),
            _ => {}
        }
    }
    o
}

fn thread_sweep() -> Vec<usize> {
    let avail = pool::available();
    let requested: Vec<usize> = match std::env::var("OCS_BENCH_THREADS") {
        Ok(list) => list
            .split(',')
            .filter_map(|s| s.trim().parse().ok())
            .collect(),
        Err(_) => vec![1, 2, 4, 8],
    };
    let mut sweep = Vec::new();
    for t in requested {
        let actual = t.clamp(1, avail);
        if !sweep.contains(&actual) {
            sweep.push(actual);
        }
    }
    if sweep.is_empty() {
        sweep.push(1);
    }
    sweep.sort_unstable();
    sweep
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

fn record(
    cases: &mut Vec<CaseRecord>,
    name: &str,
    shape: String,
    threads: usize,
    stats: &BenchStats,
    items: f64,
    serial_mean_ns: f64,
) {
    let speedup = if stats.mean_ns > 0.0 {
        serial_mean_ns / stats.mean_ns
    } else {
        0.0
    };
    cases.push(CaseRecord::from_stats(
        name,
        &shape,
        threads,
        items / (stats.mean_ns / 1e9) / 1e6,
        speedup,
        stats,
    ));
}

fn rand_i8(rng: &mut Rng, n: usize) -> Vec<i8> {
    (0..n).map(|_| (rng.below(255) as i32 - 127) as i8).collect()
}

fn main() {
    let opts = parse_opts();
    let mut r = Runner::with_filter(opts.filter.clone());
    let sweep = thread_sweep();
    let avail = pool::available();
    let mut cases: Vec<CaseRecord> = Vec::new();
    println!(
        "native GEMM harness: shapes={} threads available={} sweep={:?}",
        opts.shapes, avail, sweep
    );

    let small = opts.shapes == "small";
    // (m, k, n): batch-of-patches × inner × output channels — the
    // first shape mirrors an im2col'd conv layer, the second a fat FC
    let gemm_shapes: Vec<(usize, usize, usize)> = if small {
        vec![(128, 288, 96)]
    } else {
        vec![(256, 1152, 96), (256, 960, 256), (64, 4096, 128)]
    };

    let mut best_parallel: Option<(String, usize, f64)> = None;
    let mut best_vs_packed_serial = 0.0f64;
    for &(m, k, n) in &gemm_shapes {
        let mut rng = Rng::new(17);
        let a = rand_i8(&mut rng, m * k);
        let b = rand_i8(&mut rng, k * n);
        let scales: Vec<f32> = (0..n).map(|j| 1e-3 + j as f32 * 1e-6).collect();
        let bias: Vec<f32> = (0..n).map(|j| j as f32 * 0.01).collect();
        let shape = format!("{m}x{k}x{n}");
        let macs = (m * k * n) as f64;

        // ---- correctness gate: everything equals the naive baseline --
        let want = baseline::gemm_i8_naive(&a, &b, m, k, n);
        let pb = PackedB::pack(&b, k, n);
        assert_eq!(gemm::gemm_i8(&a, &pb, m, 1), want, "packed serial != naive");
        let tmax = *sweep.last().unwrap();
        assert_eq!(gemm::gemm_i8(&a, &pb, m, tmax), want, "packed parallel != naive");
        let dq_want = baseline::dequant_naive(&want, m, n, &scales, &bias);
        let dq_got = gemm::gemm_i8_dequant(&a, &pb, m, &scales, &bias, tmax);
        assert_eq!(bits(&dq_want), bits(&dq_got), "fused dequant != two-pass");

        // ---- timings -------------------------------------------------
        let naive = r.bench(&format!("i8_gemm/naive_serial/{shape}"), || {
            let out = baseline::gemm_i8_naive(&a, &b, m, k, n);
            std::hint::black_box(out.len());
        });
        let naive_ns = naive.as_ref().map(|s| s.mean_ns);
        if let Some(s) = &naive {
            record(
                &mut cases,
                "i8_gemm/naive_serial",
                shape.clone(),
                1,
                &s,
                macs,
                s.mean_ns,
            );
        }
        let mut packed_serial_ns = None;
        for &t in &sweep {
            let stats = r.bench(&format!("i8_gemm/packed_t{t}/{shape}"), || {
                let out = gemm::gemm_i8_dequant(&a, &pb, m, &scales, &bias, t);
                std::hint::black_box(out.len());
            });
            if let (Some(s), Some(naive_ns)) = (&stats, naive_ns) {
                record(
                    &mut cases,
                    &format!("i8_gemm/packed_t{t}"),
                    shape.clone(),
                    t,
                    &s,
                    macs,
                    naive_ns,
                );
                if t == 1 {
                    packed_serial_ns = Some(s.mean_ns);
                }
                if t > 1 {
                    let speedup = naive_ns / s.mean_ns;
                    if best_parallel.as_ref().map(|b| speedup > b.2).unwrap_or(true) {
                        best_parallel = Some((shape.clone(), t, speedup));
                    }
                    if let Some(ps) = packed_serial_ns {
                        best_vs_packed_serial = best_vs_packed_serial.max(ps / s.mean_ns);
                    }
                }
            }
        }
        // packing cost, for the record (paid once per prepared layer)
        let pack_stats = r.bench(&format!("i8_gemm/pack_b/{shape}"), || {
            let p = PackedB::pack(&b, k, n);
            std::hint::black_box(p.packed_bytes());
        });
        if let Some(s) = &pack_stats {
            record(
                &mut cases,
                "i8_gemm/pack_b",
                shape.clone(),
                1,
                &s,
                (k * n) as f64,
                s.mean_ns,
            );
        }
    }

    // ---- end-to-end: the synthetic MLP through the native engine -----
    {
        let (spec, ws) = synthetic_mlp(2027);
        let images = ocs::train::data::synth_images(64, 99).x;
        let calib = native_calibrate(&spec, &ws, &images, 32).expect("native calibration");
        let int_recipe = QuantConfig {
            w_bits: Some(8),
            a_bits: Some(8),
            w_clip: ClipMethod::Mse,
            ..QuantConfig::float()
        }
        .to_recipe();
        let int_prep =
            pipeline::prepare_recipe(&spec, &ws, Some(&calib), &int_recipe).expect("prepare");
        let int_exe = NativeExecutable::build(&spec, &int_prep).expect("build int");
        assert_eq!(int_exe.int_layers(), 2, "MLP must take the integer path");
        let float_prep =
            pipeline::prepare_recipe(&spec, &ws, None, &QuantRecipe::float()).expect("prepare");
        let float_exe = NativeExecutable::build(&spec, &float_prep).expect("build float");
        let shape = "mlp_b32".to_string();
        let imgs32 = ocs::calib::slice_rows(&images, 0, 32).unwrap();
        let fstats = r.bench("native_infer/float_b32", || {
            let y = float_exe.infer(&imgs32).unwrap();
            std::hint::black_box(y.len());
        });
        let f_ns = fstats.as_ref().map(|s| s.mean_ns);
        if let Some(s) = &fstats {
            record(
                &mut cases,
                "native_infer/float_b32",
                shape.clone(),
                1,
                &s,
                32.0,
                s.mean_ns,
            );
        }
        let istats = r.bench("native_infer/int_b32", || {
            let y = int_exe.infer(&imgs32).unwrap();
            std::hint::black_box(y.len());
        });
        if let (Some(s), Some(f_ns)) = (&istats, f_ns) {
            record(&mut cases, "native_infer/int_b32", shape, 1, &s, 32.0, f_ns);
        }
    }

    // ---- verdicts ----------------------------------------------------
    let mut failures: Vec<String> = Vec::new();
    if let Some((shape, t, speedup)) = &best_parallel {
        println!(
            "\ni8_gemm: best parallel speedup vs naive serial = {speedup:.2}x \
             (shape {shape}, {t} threads; {best_vs_packed_serial:.2}x vs packed serial)"
        );
        if avail >= 4 && *speedup < 2.0 {
            failures.push(format!(
                "packed parallel i8 GEMM only {speedup:.2}x vs naive serial (need >= 2x at 4+ threads)"
            ));
        }
    }
    if let Some(path) = &opts.json {
        let rec = BenchRecord::from_cases("native", "cpu", avail, &cases);
        rec.write(path).expect("write BENCH_native.json");
        println!("wrote {} ({} cases)", path.display(), cases.len());
    }
    if !failures.is_empty() {
        if opts.no_assert {
            for f in &failures {
                println!("WARN (no-assert): {f}");
            }
        } else {
            for f in &failures {
                eprintln!("FAIL: {f}");
            }
            std::process::exit(1);
        }
    }
}
